//! Stateful streaming inference sessions — amortized O(1) work per
//! sample over the fused-chain halo machinery.
//!
//! The paper's setting is streaming ("input sequence elements become
//! available one by one"), and PR 5's chain fusion already computes
//! exactly the state an incremental forward needs: per stage, the
//! trailing `extent − stride` input halo in a small ring buffer. A
//! [`Session`] captures that state between calls: each
//! [`Session::step_into`] appends a packet of input samples, advances
//! every stage only as far as the new samples allow (the per-stage
//! *availability* frontier), and emits the final-layer outputs that
//! just became computable — bit-identical to rerunning the full
//! forward on the extended history, because the per-element math is
//! the same row-tile conv body and pool fold the batch plan runs
//! (`chain_advance`, shared verbatim with the fused-chain sweep).
//!
//! **State layout.** Sessions live in a slab-backed [`SessionArena`]:
//! one `Vec<f32>` slab of uniform slots (input ring + per-stage chain
//! rings + a planar output staging tile) plus one `Vec<usize>` cursor
//! slab — no per-session allocations, and closed slots are recycled
//! through a free list. Opening a session may grow the slabs (tracked
//! by [`SessionArena::grows`]); stepping never does, which is the
//! zero-allocation assertion the streaming tests pin.
//!
//! **Availability.** With `a` input samples absorbed, a stage of
//! geometry `(stride s, extent e, left/right pad p)` has finalized
//! exactly `min(n_out, (a + p − e)/s + 1)` outputs while `a < n_in`
//! (only left padding is usable mid-stream), and all `n_out` once
//! `a == n_in` — the right-pad windows unlock in one burst at end of
//! stream. Composing this over the stages gives the emit count per
//! step, deterministically, before any kernel runs.
//!
//! See `docs/streaming.md` for the wire protocol and serving-side
//! lifecycle (TTL, eviction, coalescing).

use anyhow::{bail, ensure, Result};

use super::plan::{
    chain_advance, chain_input_cap, chain_task_elems, ChainDst, ChainStage, Plan,
};
use super::Model;

/// Final-stage outputs per internal advance — the session's sweep tile.
/// Small keeps the per-slot ring/staging footprint tiny (sessions are
/// many, packets are small); the halo recursion in `chain_task_elems`
/// sizes every ring for exactly this target.
pub const SESSION_TILE: usize = 8;

/// Outputs stage `st` has finalized once `avail_in` of its input rows
/// are absorbed (see the module docs for the derivation).
fn stage_avail(st: &ChainStage, avail_in: usize) -> usize {
    if avail_in >= st.n_in {
        return st.n_out;
    }
    let a = avail_in + st.pad;
    if a < st.extent {
        0
    } else {
        ((a - st.extent) / st.stride + 1).min(st.n_out)
    }
}

/// Final-stage availability after absorbing `avail_in` input samples.
fn chain_avail(stages: &[ChainStage], avail_in: usize) -> usize {
    let mut a = avail_in;
    for st in stages {
        a = stage_avail(st, a);
    }
    a
}

/// Compiled streaming geometry for one model: the plan's fused-chain
/// stage sequence re-tiled for [`SESSION_TILE`], with the slab slot
/// layout every session of this model shares.
#[derive(Clone, Debug)]
pub struct StreamSpec {
    stages: Vec<ChainStage>,
    n_layers: usize,
    c_in: usize,
    c_out: usize,
    /// Input samples per full stream (the model's `seq_len`).
    n_in: usize,
    /// Final outputs per full stream.
    n_out: usize,
    /// Input-ring row capacity (per channel).
    in_cap: usize,
    /// Chain ring-buffer elements per slot (`chain_task_elems`).
    ring_elems: usize,
    /// f32 elements per slab slot:
    /// `c_in·in_cap + ring_elems + c_out·SESSION_TILE`.
    slot_elems: usize,
    /// usize cursor words per slot: `3m` sweep cursors + input origin +
    /// absorbed count + open flag.
    cur_words: usize,
}

impl StreamSpec {
    /// Build from a batch-1 plan. Fails if any step has no streaming
    /// tile form (see [`Plan`]'s stream conversion for the rules).
    pub fn new(plan: &Plan, model: &Model) -> Result<Self> {
        let mut stages = plan.stream_stages(model)?;
        let m = stages.len();
        let ring_elems = chain_task_elems(&mut stages, SESSION_TILE);
        let in_cap = chain_input_cap(&stages, SESSION_TILE);
        let (c_in, n_in) = (stages[0].c_in, stages[0].n_in);
        let (c_out, n_out) = (stages[m - 1].c_out, stages[m - 1].n_out);
        Ok(Self {
            stages,
            n_layers: model.layer_count(),
            c_in,
            c_out,
            n_in,
            n_out,
            in_cap,
            ring_elems,
            slot_elems: c_in * in_cap + ring_elems + c_out * SESSION_TILE,
            cur_words: 3 * m + 3,
        })
    }

    pub fn in_channels(&self) -> usize {
        self.c_in
    }

    pub fn out_channels(&self) -> usize {
        self.c_out
    }

    /// Input samples a full stream carries (the model's `seq_len`).
    pub fn stream_len(&self) -> usize {
        self.n_in
    }

    /// Output samples a full stream emits.
    pub fn out_len(&self) -> usize {
        self.n_out
    }

    /// Per-session f32 state footprint.
    pub fn slot_elems(&self) -> usize {
        self.slot_elems
    }
}

/// Handle to one live session inside a [`SessionArena`]. Slot indices
/// are recycled after close; serving keeps its own generation map on
/// top (a stale wire id must not reach a recycled slot).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SessionId(u32);

impl SessionId {
    pub fn index(self) -> u32 {
        self.0
    }
}

// Cursor-word layout inside one slot's `cur` span (after the 3m sweep
// cursors prod/lo/hi).
const CUR_IN_LO: usize = 0;
const CUR_AVAIL: usize = 1;
const CUR_OPEN: usize = 2;

/// Slab-backed pool of streaming sessions for one model/plan: all
/// per-session state lives in two uniform-slot slabs, so N sessions
/// cost exactly `N · slot_elems` floats plus cursors — no per-session
/// `Vec`s, no fragmentation, and closed slots recycle via a free list.
#[derive(Clone, Debug)]
pub struct SessionArena {
    spec: StreamSpec,
    /// `[input ring | chain rings | staging]` per slot.
    slab: Vec<f32>,
    /// `[prod(m) | lo(m) | hi(m) | in_lo | avail | open]` per slot.
    cur: Vec<usize>,
    free: Vec<u32>,
    slots: usize,
    live: usize,
    grows: u64,
}

impl SessionArena {
    pub fn new(plan: &Plan, model: &Model) -> Result<Self> {
        Ok(Self {
            spec: StreamSpec::new(plan, model)?,
            slab: Vec::new(),
            cur: Vec::new(),
            free: Vec::new(),
            slots: 0,
            live: 0,
            grows: 0,
        })
    }

    pub fn spec(&self) -> &StreamSpec {
        &self.spec
    }

    /// Live (open) session count.
    pub fn live_sessions(&self) -> usize {
        self.live
    }

    /// Times the slab grew (a fresh slot was carved instead of reusing
    /// a free one). Open may grow; **step never does** — steady-state
    /// tests assert this stays flat across arbitrarily many steps.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Pre-carve capacity for `n` sessions so later opens are
    /// growth-free (the serving warm-up path).
    pub fn reserve(&mut self, n: usize) {
        while self.slots < n {
            let idx = self.slots as u32;
            self.slots += 1;
            self.slab.resize(self.slots * self.spec.slot_elems, 0.0);
            self.cur.resize(self.slots * self.spec.cur_words, 0);
            self.free.push(idx);
        }
    }

    /// Open a session: recycle a free slot or grow the slab by one.
    pub fn open(&mut self) -> SessionId {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                let i = self.slots as u32;
                self.slots += 1;
                self.slab.resize(self.slots * self.spec.slot_elems, 0.0);
                self.cur.resize(self.slots * self.spec.cur_words, 0);
                self.grows += 1;
                i
            }
        };
        self.live += 1;
        let id = SessionId(idx);
        self.reset(id);
        id
    }

    /// Rewind a session to the empty-stream state (keeps the slot).
    /// Ring contents need no zeroing: the sweep only ever reads rows it
    /// has produced since the cursors were reset.
    pub fn reset(&mut self, id: SessionId) {
        let m3 = self.spec.cur_words - 3; // 3m sweep-cursor words
        let cur = self.cur_slot_mut(id);
        for w in cur.iter_mut() {
            *w = 0;
        }
        cur[m3 + CUR_OPEN] = 1;
    }

    /// Close a session and recycle its slot.
    pub fn close(&mut self, id: SessionId) -> Result<()> {
        let m3 = self.spec.cur_words - 3;
        let cur = self.cur_slot_mut(id);
        ensure!(cur[m3 + CUR_OPEN] == 1, "session already closed");
        cur[m3 + CUR_OPEN] = 0;
        self.live -= 1;
        self.free.push(id.0);
        Ok(())
    }

    /// Input samples this session has absorbed so far.
    pub fn samples_seen(&self, id: SessionId) -> usize {
        let m3 = self.spec.cur_words - 3;
        self.cur[id.0 as usize * self.spec.cur_words + m3 + CUR_AVAIL]
    }

    /// Whether the session has absorbed its full stream.
    pub fn finished(&self, id: SessionId) -> bool {
        self.samples_seen(id) >= self.spec.n_in
    }

    /// Output *samples* (per-sample rows of `out_channels` floats) that
    /// pushing `n_new` more input samples would emit.
    pub fn pending_out_samples(&self, id: SessionId, n_new: usize) -> usize {
        let m = self.spec.stages.len();
        let base = id.0 as usize * self.spec.cur_words;
        let avail = self.cur[base + 3 * m + CUR_AVAIL];
        let prod_final = self.cur[base + m - 1];
        chain_avail(&self.spec.stages, (avail + n_new).min(self.spec.n_in)) - prod_final
    }

    fn cur_slot_mut(&mut self, id: SessionId) -> &mut [usize] {
        &mut self.cur[id.0 as usize * self.spec.cur_words..][..self.spec.cur_words]
    }

    /// Advance session `id` by the packet `x` (interleaved `[t, c]`:
    /// `x[j·c_in + ch]` is sample `j`, channel `ch`), writing the
    /// outputs that just became final into the prefix of `dst`
    /// (interleaved the same way) and returning the emitted *sample*
    /// count `r` — `dst[..r·out_channels]` is fully overwritten, the
    /// rest untouched. `model` must be the model the arena was built
    /// from. Emits are bit-identical to the batch forward on the full
    /// history; pushing beyond the model's `seq_len` is an error.
    ///
    /// Steady-state cost: O(packet) kernel work plus O(stages) cursor
    /// arithmetic — amortized O(1) per sample — and zero allocations
    /// (all state is pre-carved slab).
    pub fn step_into(
        &mut self,
        id: SessionId,
        model: &Model,
        x: &[f32],
        dst: &mut [f32],
    ) -> Result<usize> {
        let spec = &self.spec;
        let m = spec.stages.len();
        ensure!((id.0 as usize) < self.slots, "unknown session id");
        ensure!(
            model.layer_count() == spec.n_layers,
            "session arena built for a different model (layer count {} vs {})",
            spec.n_layers,
            model.layer_count()
        );
        ensure!(
            x.len() % spec.c_in == 0,
            "packet length {} is not a multiple of c_in = {}",
            x.len(),
            spec.c_in
        );
        let samples = x.len() / spec.c_in;
        let base = id.0 as usize * spec.cur_words;
        ensure!(self.cur[base + 3 * m + CUR_OPEN] == 1, "session is closed");
        let mut s_avail = self.cur[base + 3 * m + CUR_AVAIL];
        let mut s_in_lo = self.cur[base + 3 * m + CUR_IN_LO];
        ensure!(
            s_avail + samples <= spec.n_in,
            "packet overruns the stream: {} absorbed + {} new > seq_len {}",
            s_avail,
            samples,
            spec.n_in
        );
        // Emit count is deterministic from the availability math alone —
        // check the caller's buffer before touching any state.
        let prod_final0 = self.cur[base + m - 1];
        let r = chain_avail(&spec.stages, s_avail + samples) - prod_final0;
        ensure!(
            dst.len() >= r * spec.c_out,
            "dst holds {} floats, step emits {} samples × {} channels",
            dst.len(),
            r,
            spec.c_out
        );
        crate::check::poison(&mut dst[..r * spec.c_out]);

        // Carve this slot's state: input ring rows, chain rings,
        // planar staging — then the cursor words.
        let slab = &mut self.slab[id.0 as usize * spec.slot_elems..][..spec.slot_elems];
        let (input_ring, rest) = slab.split_at_mut(spec.c_in * spec.in_cap);
        let (task_buf, staging) = rest.split_at_mut(spec.ring_elems);
        let cur = &mut self.cur[base..][..spec.cur_words];
        let (prod, rest_c) = cur.split_at_mut(m);
        let (lo, rest_c) = rest_c.split_at_mut(m);
        let (hi, _tail) = rest_c.split_at_mut(m);

        let mut xoff = 0usize;
        while xoff < samples {
            let c = (samples - xoff).min(SESSION_TILE);
            // Drop input rows every stage has consumed; the retained
            // halo shifts to the ring front. (`prod[0]` only moves
            // forward, so `in_lo` is monotone and rows the sweep still
            // needs are never dropped.)
            let keep = spec.stages[0].in_lo(prod[0]).min(s_avail);
            if keep > s_in_lo {
                let have = s_avail - keep;
                if have > 0 {
                    let shift = keep - s_in_lo;
                    for row in input_ring.chunks_mut(spec.in_cap) {
                        row.copy_within(shift..shift + have, 0);
                    }
                }
                s_in_lo = keep;
            }
            crate::invariant!(
                s_avail + c - s_in_lo <= spec.in_cap,
                "session input ring overflow"
            );
            // Append the packet chunk, de-interleaving [t, c] → rows.
            for j in 0..c {
                for ch in 0..spec.c_in {
                    input_ring[ch * spec.in_cap + (s_avail - s_in_lo + j)] =
                        x[(xoff + j) * spec.c_in + ch];
                }
            }
            s_avail += c;
            xoff += c;
            // Advance in SESSION_TILE bites up to the new availability
            // frontier. Mid-stream this is at most one bite; the
            // end-of-stream right-pad burst may take several (rings are
            // sized per bite, so the target must stay capped).
            let avail_final = chain_avail(&spec.stages, s_avail);
            loop {
                let t_base = prod[m - 1];
                let target = avail_final.min(t_base + SESSION_TILE);
                if target <= t_base {
                    break;
                }
                chain_advance(
                    &spec.stages,
                    model,
                    &*input_ring,
                    s_in_lo,
                    spec.in_cap,
                    task_buf,
                    prod,
                    lo,
                    hi,
                    target,
                    ChainDst::Planar {
                        buf: &mut *staging,
                        cap: SESSION_TILE,
                        lo: t_base,
                    },
                );
                // Drain the staging tile to the caller, re-interleaving
                // rows → [t, c].
                let n_new = prod[m - 1] - t_base;
                for j in 0..n_new {
                    let t = t_base - prod_final0 + j;
                    for co in 0..spec.c_out {
                        dst[t * spec.c_out + co] = staging[co * SESSION_TILE + j];
                    }
                }
            }
        }
        debug_assert_eq!(prod[m - 1] - prod_final0, r);
        self.cur[base + 3 * m + CUR_AVAIL] = s_avail;
        self.cur[base + 3 * m + CUR_IN_LO] = s_in_lo;
        crate::check::assert_no_poison(&dst[..r * spec.c_out], "SessionArena::step_into");
        Ok(r)
    }
}

/// Single-session convenience wrapper: an arena with one slot.
pub struct Session {
    arena: SessionArena,
    id: SessionId,
}

impl Session {
    /// Capture streaming state for `plan` (compiled at batch 1 from
    /// `model`).
    pub fn open(plan: &Plan, model: &Model) -> Result<Self> {
        let mut arena = SessionArena::new(plan, model)?;
        let id = arena.open();
        Ok(Self { arena, id })
    }

    /// See [`SessionArena::step_into`].
    pub fn step_into(&mut self, model: &Model, x: &[f32], dst: &mut [f32]) -> Result<usize> {
        self.arena.step_into(self.id, model, x, dst)
    }

    /// Output samples the next `n_new`-sample packet would emit.
    pub fn pending_out_samples(&self, n_new: usize) -> usize {
        self.arena.pending_out_samples(self.id, n_new)
    }

    /// Rewind to the empty-stream state (state slot is kept).
    pub fn reset(&mut self) {
        self.arena.reset(self.id);
    }

    pub fn samples_seen(&self) -> usize {
        self.arena.samples_seen(self.id)
    }

    pub fn finished(&self) -> bool {
        self.arena.finished(self.id)
    }

    pub fn spec(&self) -> &StreamSpec {
        self.arena.spec()
    }

    /// Slab growths since open — stays at the open-time value forever
    /// if stepping is truly allocation-free.
    pub fn grows(&self) -> u64 {
        self.arena.grows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::load_config;
    use crate::conv::{BackendChoice, ConvBackend};
    use crate::nn::{EagerScratch, PlannerConfig};
    use crate::workload::Rng;

    const CHAIN_CFG: &str = r#"
[model]
name = "stream-t"
c_in = 2
seq_len = 64

[layer.0]
type = "conv"
c_out = 4
k = 5

[layer.1]
type = "conv"
c_out = 4
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "conv"
c_out = 3
k = 3
"#;

    fn build() -> (Model, Plan) {
        let (mc, _) = load_config(CHAIN_CFG).unwrap();
        let mut rng = Rng::new(7);
        let model = Model::init(&mc, &mut rng).unwrap();
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Sliding),
            ..PlannerConfig::default()
        };
        let plan = Plan::compile(&model, 1, &cfg).unwrap();
        (model, plan)
    }

    /// Planar [c, n] eager output for the full input.
    fn oracle(model: &Model, x: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        model
            .forward_eager_into(
                x,
                1,
                ConvBackend::Sliding,
                &mut EagerScratch::default(),
                &mut out,
            )
            .unwrap();
        out
    }

    /// Interleave planar [c, n] to [t, c].
    fn interleave(planar: &[f32], c: usize) -> Vec<f32> {
        let n = planar.len() / c;
        let mut out = vec![0.0; planar.len()];
        for t in 0..n {
            for ch in 0..c {
                out[t * c + ch] = planar[ch * n + t];
            }
        }
        out
    }

    #[test]
    fn session_matches_eager_forward() {
        let (model, plan) = build();
        let mut rng = Rng::new(9);
        let n = model.seq_len;
        let c_in = model.c_in;
        // Planar input for the oracle, interleaved for the session.
        let planar: Vec<f32> = rng.vec_uniform(c_in * n, -1.0, 1.0);
        let stream = interleave(&planar, c_in);
        let want = interleave(&oracle(&model, &planar), model.out_shape().0);

        let mut sess = Session::open(&plan, &model).unwrap();
        let c_out = sess.spec().out_channels();
        let mut got: Vec<f32> = Vec::new();
        let mut dst = vec![0.0f32; sess.spec().out_len() * c_out];
        for chunk in stream.chunks(5 * c_in) {
            let r = sess.step_into(&model, chunk, &mut dst).unwrap();
            got.extend_from_slice(&dst[..r * c_out]);
        }
        assert!(sess.finished());
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "output {i}: {a} vs {b}");
        }
    }

    #[test]
    fn arena_recycles_slots_without_growth() {
        let (model, plan) = build();
        let mut arena = SessionArena::new(&plan, &model).unwrap();
        let a = arena.open();
        let b = arena.open();
        assert_eq!(arena.grows(), 2);
        assert_eq!(arena.live_sessions(), 2);
        arena.close(a).unwrap();
        assert!(arena.close(a).is_err(), "double close must fail");
        let c = arena.open();
        assert_eq!(arena.grows(), 2, "recycled slot must not grow the slab");
        assert_eq!(arena.live_sessions(), 2);
        arena.close(b).unwrap();
        arena.close(c).unwrap();
        assert_eq!(arena.live_sessions(), 0);
    }

    #[test]
    fn step_past_end_of_stream_errors() {
        let (model, plan) = build();
        let mut sess = Session::open(&plan, &model).unwrap();
        let n = sess.spec().stream_len();
        let c_in = sess.spec().in_channels();
        let x = vec![0.5f32; n * c_in];
        let mut dst = vec![0.0f32; sess.spec().out_len() * sess.spec().out_channels()];
        sess.step_into(&model, &x, &mut dst).unwrap();
        assert!(sess.step_into(&model, &x[..c_in], &mut dst).is_err());
        sess.reset();
        assert_eq!(sess.samples_seen(), 0);
        let r = sess.step_into(&model, &x[..c_in], &mut dst).unwrap();
        assert_eq!(r, 0, "one sample cannot complete the first window");
        assert_eq!(sess.samples_seen(), 1);
    }

    #[test]
    fn residual_and_dense_models_refuse_sessions() {
        let cfg = r#"
[model]
name = "nostream"
c_in = 1
seq_len = 32

[layer.0]
type = "conv"
c_out = 2
k = 3

[layer.1]
type = "dense"
out = 4
"#;
        let (mc, _) = load_config(cfg).unwrap();
        let mut rng = Rng::new(3);
        let model = Model::init(&mc, &mut rng).unwrap();
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Sliding),
            ..PlannerConfig::default()
        };
        let plan = Plan::compile(&model, 1, &cfg).unwrap();
        let err = Session::open(&plan, &model).unwrap_err();
        assert!(err.to_string().contains("dense"), "{err}");
    }
}
