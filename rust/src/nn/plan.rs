//! Compile-once execution plans for the layer stack.
//!
//! [`Plan::compile`] runs once per `(model, batch-bucket, backend
//! choice)` and bakes every per-request decision out of the hot path:
//!
//! * **Shape resolution** — every layer's [`Conv1dParams`] /
//!   [`Pool1dParams`] (with the batch folded in) is derived ahead of
//!   time; execution never re-derives a shape.
//! * **Per-layer kernel selection** — each conv-bearing layer gets a
//!   [`PlanKernel`] from, in priority order: the layer's `backend =`
//!   override in the model TOML, the deployment-level
//!   [`BackendChoice::Fixed`] backend, or (under
//!   [`BackendChoice::Auto`]) either the shape-based cost model in
//!   [`choose_kernel`] or — when [`PlannerConfig::autotune`] is set —
//!   a **measured** choice: compile micro-probes every candidate kernel
//!   (sliding / small_k / im2col+GEMM / direct) against the layer's
//!   real shape and weights and picks the fastest, caching the result
//!   in the process-wide [`TuneCache`] keyed by `(layer shape, SIMD
//!   tier, executor threads)` so repeated compiles are free. The shape
//!   heuristic was hand-fit to one machine; the probe makes the
//!   crossover (sliding wins at large filters, GEMM at small filters
//!   with fat channel reductions) portable across microarchitectures.
//! * **Operator fusion** — a conv directly followed by a
//!   non-overlapping pool (`stride ≥ w`, the common 2× down-sampling
//!   case) fuses into a single arena pass when the conv runs the
//!   sliding kernel: each worker computes one conv row into a small
//!   cache-resident row buffer and folds the pool windows straight out
//!   of it, so the full dense conv activation never round-trips through
//!   the arena. Fused execution reuses the *exact* per-row conv kernel
//!   and the *exact* non-overlapping fold of the unfused path, so it is
//!   bit-identical to running the two steps separately.
//! * **Arena layout** — one flat `Vec<f32>` holds every intermediate:
//!   `[ act A | act B | residual tmp | im2col col | fuse rows ]`, with
//!   region sizes (`act_len`, `tmp_len`, `col_len`, `fuse_len`)
//!   precomputed at compile time. Step *i* reads one activation region
//!   and writes the other (alternating; step 0 reads the request input,
//!   the last step writes the caller's output buffer), so execution
//!   does no resizing, no ping/pong `Vec` swaps, and — for all kernels
//!   except the faithful-math `SlidingPair` — no allocation at all
//!   after warm-up.
//! * **Fused epilogues** — bias is already part of the kernels'
//!   accumulator seed; the ReLU tail and the residual skip-add ride the
//!   kernels' destination writes as an [`Epilogue`] instead of separate
//!   memory passes.
//!
//! [`Plan::run_into`] is bit-identical to the eager reference path
//! ([`Model::forward_eager_into`]) for every fixed backend, thread
//! count, and SIMD tier — enforced by `tests/plan_parity.rs` (which
//! also pins autotuned and fused plans to the eager path with matching
//! per-layer kernels). The serving engines compile and cache plans
//! keyed by batch size ([`crate::coordinator::NativeEngine`]
//! additionally precompiles a configured set of batch buckets at
//! startup, so no request ever pays compile-or-probe latency); the
//! eager [`Model::forward_into`] is itself a compile-then-run wrapper.

use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::conv::{self, BackendChoice, Conv1dParams, ConvBackend};
use crate::exec::{Executor, PAR_MIN_FANOUT};
use crate::ops::Epilogue;
use crate::pool::{pool1d_row_nonoverlap, pool1d_with_into, Pool1dParams, PoolKind};
use crate::simd::SimdTier;

use super::layers::{dense_forward, Layer};
use super::Model;

/// Which kernel executes a planned layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanKernel {
    /// Broadcast-FMA sliding-window conv (the paper's contribution).
    Sliding,
    /// im2col + blocked GEMM, column matrix in the plan arena.
    Im2col,
    /// Fused register-blocked small-filter kernel (k ∈ {3, 5}).
    SmallK,
    /// Nested-loop reference conv.
    Direct,
    /// Literal Eq. 7–9 pair-operator prefix sum (allocates; kept for
    /// fidelity, never chosen by the cost model).
    SlidingPair,
    /// Blocked-GEMM gemv (dense layers).
    Gemm,
    /// Sliding-sum pooling.
    Pool,
    /// Fused conv→pool step: sliding conv rows folded straight into the
    /// non-overlapping pool output (one arena pass for two layers).
    FusedSlidingPool,
}

impl PlanKernel {
    pub fn name(&self) -> &'static str {
        match self {
            PlanKernel::Sliding => "sliding",
            PlanKernel::Im2col => "im2col",
            PlanKernel::SmallK => "small_k",
            PlanKernel::Direct => "direct",
            PlanKernel::SlidingPair => "sliding_pair",
            PlanKernel::Gemm => "gemm",
            PlanKernel::Pool => "pool",
            PlanKernel::FusedSlidingPool => "sliding+pool",
        }
    }
}

/// Planner inputs beyond the model itself.
#[derive(Clone, Copy, Debug)]
pub struct PlannerConfig {
    /// Deployment-level backend selection (`--backend` /
    /// `serve.backend`); per-layer TOML overrides beat it either way.
    pub backend: BackendChoice,
    /// Measured-cost kernel selection: when the decision falls to the
    /// cost model (`Auto` backend, no per-layer override), micro-probe
    /// every candidate kernel against the layer's real shape and
    /// weights and pick the fastest instead of trusting the shape
    /// heuristic. Probe results live in the global [`TuneCache`], so
    /// repeated compiles of the same shape are free.
    pub autotune: bool,
    /// Plan-level conv→pool fusion: fold a non-overlapping pool
    /// directly over its preceding sliding-conv rows (bit-identical to
    /// the unfused plan; on by default).
    pub fuse: bool,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            backend: BackendChoice::default(),
            autotune: false,
            fuse: true,
        }
    }
}

/// One compiled layer step: resolved shapes + chosen kernel. The arena
/// region a step reads/writes follows its position (alternating A/B;
/// first reads the input, last writes the output), so the step itself
/// only carries lengths. A fused step covers two adjacent layers.
#[derive(Clone, Debug)]
struct Step {
    /// Index into the model's layer stack of the step's *first* layer
    /// (weight lookup + validation).
    layer: usize,
    kernel: PlanKernel,
    op: StepOp,
    /// Input elements (`batch · c · n`).
    in_len: usize,
    /// Output elements (`batch · c2 · n2`).
    out_len: usize,
}

#[derive(Clone, Debug)]
enum StepOp {
    Conv { p: Conv1dParams, relu: bool },
    Residual { p: Conv1dParams },
    Pool { kind: PoolKind, p: Pool1dParams },
    Dense { feat: usize, out: usize, relu: bool },
    /// Fused conv→pool pair: the pool folds straight over per-row conv
    /// output buffers in the arena's fuse region.
    ConvPool {
        conv: Conv1dParams,
        relu: bool,
        kind: PoolKind,
        pool: Pool1dParams,
    },
}

/// Upper bound on concurrent row buffers for a fused conv→pool step —
/// bounds the arena's fuse region to `FUSE_MAX_TASKS · n_conv` elements
/// instead of the full dense conv activation.
const FUSE_MAX_TASKS: usize = 16;

/// The scratch a plan executes in: one flat arena
/// `[act A | act B | tmp | col | fuse]`, grown once to the plan's
/// precomputed size and recycled dirty across requests.
#[derive(Clone, Debug, Default)]
pub struct PlanScratch {
    arena: Vec<f32>,
}

impl PlanScratch {
    /// Pre-grow the arena to `elems` (engine startup precompilation):
    /// the first request then performs zero allocations.
    pub fn reserve(&mut self, elems: usize) {
        if self.arena.len() < elems {
            self.arena.resize(elems, 0.0);
        }
    }

    /// Current arena size in elements — the allocation-audit surface
    /// (steady-state serving must never grow it).
    pub fn capacity(&self) -> usize {
        self.arena.len()
    }
}

/// Keyed compile-once plan cache (tiny linear scan — one entry per
/// batch bucket / backend pair) with hit/compile counters so serving
/// tests can assert that steady-state inference never compiles. Shared
/// by [`crate::coordinator::NativeEngine`] (keyed by batch size) and
/// [`super::ForwardScratch`](crate::nn::ForwardScratch) (keyed by
/// batch + backend).
#[derive(Clone, Debug)]
pub struct PlanCache<K> {
    entries: Vec<(K, Plan)>,
    hits: u64,
    compiles: u64,
}

impl<K> Default for PlanCache<K> {
    fn default() -> Self {
        Self {
            entries: Vec::new(),
            hits: 0,
            compiles: 0,
        }
    }
}

impl<K: PartialEq + Copy> PlanCache<K> {
    /// The cached plan for `key`, compiling (and caching) on first use.
    pub fn get_or_compile(
        &mut self,
        key: K,
        compile: impl FnOnce() -> Result<Plan>,
    ) -> Result<&Plan> {
        let idx = match self.entries.iter().position(|(k, _)| *k == key) {
            Some(i) => {
                self.hits += 1;
                i
            }
            None => {
                self.entries.push((key, compile()?));
                self.compiles += 1;
                self.entries.len() - 1
            }
        };
        Ok(&self.entries[idx].1)
    }

    /// Number of compiled plans cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups served from the cache (no compile).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Plan compilations performed (cache misses).
    pub fn compiles(&self) -> u64 {
        self.compiles
    }
}

// ───────────────────────── measured cost model ────────────────────────

/// One probed candidate: the kernel and its best measured wall time.
#[derive(Clone, Copy, Debug)]
pub struct ProbeResult {
    pub kernel: PlanKernel,
    /// Best-of-`PROBE_ITERS` wall time in microseconds.
    pub micros: f64,
}

/// Per-layer autotune record kept on the compiled [`Plan`] so the
/// heuristic-vs-measured decision stays auditable (the `e2e_serving`
/// bench prints these next to throughput).
#[derive(Clone, Debug)]
pub struct LayerTune {
    /// Model layer index the probe ran for.
    pub layer: usize,
    pub chosen: PlanKernel,
    /// `true` when the choice came from the [`TuneCache`] (probes then
    /// stay empty — the work happened in an earlier compile).
    pub cached: bool,
    pub probes: Vec<ProbeResult>,
}

/// Timed probe runs per candidate (after one untimed warm-up run); the
/// minimum is taken — short kernels are noisy and min is the robust
/// estimator for "how fast can this kernel go here".
const PROBE_ITERS: usize = 3;

/// Cache key for a probed decision. The shape captures everything the
/// kernels' cost depends on (batch, channels, length, filter, stride,
/// dilation, padding); the SIMD tier and executor width capture the
/// machine configuration — forcing `SWSNN_SIMD=generic` or changing
/// `--threads` re-probes rather than reusing a measurement taken under
/// different kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct TuneKey {
    shape: Conv1dParams,
    tier: SimdTier,
    threads: usize,
}

#[derive(Default)]
struct TuneInner {
    entries: Vec<(TuneKey, PlanKernel)>,
    hits: u64,
    misses: u64,
}

/// Process-wide cache of measured kernel choices, keyed by
/// `(layer shape, SIMD tier, executor threads)`. Shared across engines,
/// batch buckets, and coordinator workers so each distinct shape is
/// probed once per process no matter how many plans compile.
#[derive(Default)]
pub struct TuneCache {
    inner: Mutex<TuneInner>,
}

impl TuneCache {
    /// The process-wide cache.
    pub fn global() -> &'static TuneCache {
        static GLOBAL: OnceLock<TuneCache> = OnceLock::new();
        GLOBAL.get_or_init(TuneCache::default)
    }

    fn lookup(&self, key: &TuneKey) -> Option<PlanKernel> {
        let mut g = self.inner.lock().unwrap();
        let found = g.entries.iter().find(|(k, _)| k == key).map(|(_, v)| *v);
        if found.is_some() {
            g.hits += 1;
        } else {
            g.misses += 1;
        }
        found
    }

    /// Insert-or-get: the first inserted decision is canonical. Two
    /// replicated workers can probe the same shape concurrently (both
    /// miss `lookup`, then race here); the loser adopts the winner's
    /// kernel instead of keeping its own measurement, so every worker's
    /// plans execute the same kernels — identical requests stay
    /// bit-identical across workers.
    fn insert(&self, key: TuneKey, kernel: PlanKernel) -> PlanKernel {
        let mut g = self.inner.lock().unwrap();
        if let Some((_, existing)) = g.entries.iter().find(|(k, _)| *k == key) {
            return *existing;
        }
        g.entries.push((key, kernel));
        kernel
    }

    /// Distinct probed decisions cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups answered from the cache.
    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    /// Lookups that had to probe.
    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }
}

/// Reused probe buffers (compile-time only — probing allocates once per
/// compile, never on the request path).
#[derive(Default)]
struct ProbeScratch {
    x: Vec<f32>,
    y: Vec<f32>,
    col: Vec<f32>,
}

impl ProbeScratch {
    /// Size the buffers for one layer shape and fill the input with a
    /// small deterministic non-zero pattern (denormals/zeros can skew
    /// kernel timing).
    fn fill(&mut self, p: &Conv1dParams) {
        self.x.clear();
        self.x
            .extend((0..p.x_len()).map(|i| ((i % 29) as f32) * 0.0625 - 0.875));
        self.y.resize(p.y_len(), 0.0);
        self.col.resize(p.c_in * p.k * p.n_out(), 0.0);
    }
}

/// Run every candidate kernel against the layer's real shape and
/// weights; returns the measured times in candidate order.
fn probe_candidates(
    ex: &Executor,
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    scratch: &mut ProbeScratch,
) -> Result<Vec<ProbeResult>> {
    let mut cands = vec![PlanKernel::Sliding];
    if conv::small_k_qualifies(p) {
        cands.push(PlanKernel::SmallK);
    }
    cands.push(PlanKernel::Im2col);
    cands.push(PlanKernel::Direct);
    scratch.fill(p);
    let mut out = Vec::with_capacity(cands.len());
    for kernel in cands {
        // Untimed warm-up: fault in buffers, settle the dispatch.
        run_conv(
            ex,
            kernel,
            &scratch.x,
            w,
            bias,
            p,
            Epilogue::None,
            &mut scratch.col,
            &mut scratch.y,
        )?;
        let mut best = f64::INFINITY;
        for _ in 0..PROBE_ITERS {
            let t0 = Instant::now();
            run_conv(
                ex,
                kernel,
                &scratch.x,
                w,
                bias,
                p,
                Epilogue::None,
                &mut scratch.col,
                &mut scratch.y,
            )?;
            best = best.min(t0.elapsed().as_secs_f64() * 1e6);
        }
        out.push(ProbeResult { kernel, micros: best });
    }
    Ok(out)
}

/// Measured kernel choice for one layer: consult the [`TuneCache`],
/// probe on a miss, record the decision on the plan's tune log either
/// way.
fn measured_kernel(
    ex: &Executor,
    layer: usize,
    p: &Conv1dParams,
    w: &[f32],
    bias: Option<&[f32]>,
    probe: &mut ProbeScratch,
    tunes: &mut Vec<LayerTune>,
) -> Result<PlanKernel> {
    let key = TuneKey {
        shape: *p,
        tier: crate::simd::tier(),
        threads: ex.threads(),
    };
    if let Some(kernel) = TuneCache::global().lookup(&key) {
        tunes.push(LayerTune {
            layer,
            chosen: kernel,
            cached: true,
            probes: Vec::new(),
        });
        return Ok(kernel);
    }
    let probes = probe_candidates(ex, w, bias, p, probe)?;
    let mut chosen = probes[0];
    for pr in &probes[1..] {
        // Strict `<`: ties keep the earlier candidate (sliding first —
        // the paper's kernel wins the coin flips).
        if pr.micros < chosen.micros {
            chosen = *pr;
        }
    }
    // The cache's first writer wins: adopt whatever it returns so
    // concurrently probing workers all run the same kernel.
    let canonical = TuneCache::global().insert(key, chosen.kernel);
    tunes.push(LayerTune {
        layer,
        chosen: canonical,
        cached: false,
        probes,
    });
    Ok(canonical)
}

/// A compiled execution plan for one `(model, batch)` pair. Cheap to
/// clone (no parameter copies — weights stay in the [`Model`] the plan
/// is run against).
#[derive(Clone, Debug)]
pub struct Plan {
    batch: usize,
    steps: Vec<Step>,
    /// Model layer count the plan was compiled from (≥ `steps.len()`;
    /// fusion folds adjacent layers into one step).
    n_layers: usize,
    /// Elements per activation ping/pong region (max intermediate).
    act_len: usize,
    /// Elements for the residual intermediate region.
    tmp_len: usize,
    /// Elements for the im2col column region (largest im2col layer).
    col_len: usize,
    /// Elements for the fused conv→pool row buffers (largest fused
    /// step; zero when nothing fused).
    fuse_len: usize,
    in_len: usize,
    out_c: usize,
    out_n: usize,
    /// Autotune audit log (empty unless compiled with
    /// [`PlannerConfig::autotune`]).
    tunes: Vec<LayerTune>,
}

/// Shape-based kernel choice for a conv-shaped layer under `Auto`.
///
/// The heuristic mirrors the paper's Fig-1 crossover plus the §5
/// small-filter note:
/// * the fused small-k kernel when it applies (single channel, unit
///   stride/dilation, k ∈ {3, 5} — highest arithmetic intensity per
///   load of all paths);
/// * im2col + GEMM when the channel reduction is fat enough to feed the
///   8×8 microkernel (`c_out ≥ 8`, `c_in·k ≥ 48`) **and** the receptive
///   field is small (`effective_k ≤ 9`) — there the sliding schedule
///   degenerates to a few short passes while the k× expansion stays
///   cheap;
/// * the sliding kernel everywhere else (large filters, thin channel
///   counts, dilated stacks — the shapes the paper shows it winning).
///
/// These boundaries were hand-fit to one machine; the measured mode
/// ([`PlannerConfig::autotune`]) exists because they do not transfer.
/// The heuristic stays as the probe-free default and its boundaries are
/// pinned by unit tests so autotune work cannot silently shift them.
pub fn choose_kernel(p: &Conv1dParams) -> PlanKernel {
    if conv::small_k_qualifies(p) {
        PlanKernel::SmallK
    } else if p.c_out >= 8 && p.c_in * p.k >= 48 && p.effective_k() <= 9 {
        PlanKernel::Im2col
    } else {
        PlanKernel::Sliding
    }
}

fn kernel_for_backend(b: ConvBackend) -> PlanKernel {
    match b {
        ConvBackend::Sliding => PlanKernel::Sliding,
        ConvBackend::Im2colGemm => PlanKernel::Im2col,
        ConvBackend::Direct => PlanKernel::Direct,
        ConvBackend::SlidingPair => PlanKernel::SlidingPair,
    }
}

/// Kernel choice for one conv-shaped layer. Priority: per-layer TOML
/// override > fixed deployment backend > measured probe (autotune) >
/// shape heuristic.
#[allow(clippy::too_many_arguments)]
fn select_kernel(
    model: &Model,
    cfg: &PlannerConfig,
    layer: usize,
    p: &Conv1dParams,
    w: &[f32],
    bias: Option<&[f32]>,
    ex: &Executor,
    probe: &mut ProbeScratch,
    tunes: &mut Vec<LayerTune>,
) -> Result<PlanKernel> {
    Ok(match model.backend_override(layer) {
        Some(b) => kernel_for_backend(b),
        None => match cfg.backend {
            BackendChoice::Fixed(b) => kernel_for_backend(b),
            BackendChoice::Auto if cfg.autotune => {
                measured_kernel(ex, layer, p, w, bias, probe, tunes)?
            }
            BackendChoice::Auto => choose_kernel(p),
        },
    })
}

impl Plan {
    /// Compile the model for one batch size. Runs once per batch bucket;
    /// everything shape- or choice-dependent happens here — including
    /// the autotune probes and the conv→pool fusion pass.
    pub fn compile(model: &Model, batch: usize, cfg: &PlannerConfig) -> Result<Plan> {
        ensure!(batch >= 1, "plan batch must be >= 1");
        ensure!(
            model.layer_count() > 0,
            "cannot compile a plan for an empty model"
        );
        let nlayers = model.layer_count();
        let layers = model.layers();
        let ex = Executor::global();
        let (mut c, mut n) = (model.c_in, model.seq_len);
        let mut steps = Vec::with_capacity(nlayers);
        let (mut act_len, mut tmp_len) = (0usize, 0usize);
        let (mut col_len, mut fuse_len) = (0usize, 0usize);
        let mut tunes: Vec<LayerTune> = Vec::new();
        let mut probe = ProbeScratch::default();
        let mut i = 0usize;
        while i < nlayers {
            let layer = &layers[i];
            let in_len = batch * c * n;
            let (mut kernel, mut op) = match layer {
                Layer::Conv {
                    c_in,
                    c_out,
                    k,
                    stride,
                    dilation,
                    same_pad,
                    relu,
                    w,
                    b,
                } => {
                    ensure!(c == *c_in, "layer {i}: conv input channels");
                    let mut p = Conv1dParams::new(*c_in, *c_out, n, *k)
                        .with_batch(batch)
                        .with_stride(*stride)
                        .with_dilation(*dilation);
                    if *same_pad {
                        p = p.with_same_pad();
                    }
                    let kernel =
                        select_kernel(model, cfg, i, &p, w, Some(b), ex, &mut probe, &mut tunes)?;
                    if kernel == PlanKernel::Im2col {
                        col_len = col_len.max(p.c_in * p.k * p.n_out());
                    }
                    (kernel, StepOp::Conv { p, relu: *relu })
                }
                Layer::Residual {
                    c: cr,
                    k,
                    dilation,
                    w1,
                    b1,
                    ..
                } => {
                    ensure!(c == *cr, "layer {i}: residual channels");
                    let p = Conv1dParams::new(*cr, *cr, n, *k)
                        .with_batch(batch)
                        .with_dilation(*dilation)
                        .with_same_pad();
                    let kernel =
                        select_kernel(model, cfg, i, &p, w1, Some(b1), ex, &mut probe, &mut tunes)?;
                    if kernel == PlanKernel::Im2col {
                        col_len = col_len.max(p.c_in * p.k * p.n_out());
                    }
                    tmp_len = tmp_len.max(in_len);
                    (kernel, StepOp::Residual { p })
                }
                Layer::Pool { kind, w, stride } => {
                    let p = Pool1dParams::new(c, n, *w).with_batch(batch).with_stride(*stride);
                    (PlanKernel::Pool, StepOp::Pool { kind: *kind, p })
                }
                Layer::Dense {
                    in_features,
                    out,
                    relu,
                    ..
                } => {
                    ensure!(c * n == *in_features, "layer {i}: dense input features");
                    (
                        PlanKernel::Gemm,
                        StepOp::Dense {
                            feat: *in_features,
                            out: *out,
                            relu: *relu,
                        },
                    )
                }
            };
            let (mut c2, mut n2) = layer.out_shape(c, n);
            ensure!(n2 > 0, "layer {i} produces empty output (c={c}, n={n})");
            let mut consumed = 1usize;
            // Fusion pass: a sliding conv directly feeding a
            // non-overlapping pool (`stride ≥ w`, stride > 1, valid
            // boundary — the plan's pools are always valid-mode) folds
            // into one step. Restricted to the sliding kernel because
            // the fused executor reuses its per-row body verbatim.
            if cfg.fuse && kernel == PlanKernel::Sliding && i + 1 < nlayers {
                let conv_info = match &op {
                    StepOp::Conv { p, relu } => Some((*p, *relu)),
                    _ => None,
                };
                if let Some((cp, relu)) = conv_info {
                    if let Layer::Pool {
                        kind,
                        w: pw,
                        stride: ps,
                    } = &layers[i + 1]
                    {
                        if *ps > 1 && *ps >= *pw {
                            let pool_p = Pool1dParams::new(c2, n2, *pw)
                                .with_batch(batch)
                                .with_stride(*ps);
                            let (c3, n3) = layers[i + 1].out_shape(c2, n2);
                            ensure!(
                                n3 > 0,
                                "layer {} produces empty output (c={c2}, n={n2})",
                                i + 1
                            );
                            let rows = batch * cp.c_out;
                            fuse_len = fuse_len.max(rows.min(FUSE_MAX_TASKS) * cp.n_out());
                            kernel = PlanKernel::FusedSlidingPool;
                            op = StepOp::ConvPool {
                                conv: cp,
                                relu,
                                kind: *kind,
                                pool: pool_p,
                            };
                            c2 = c3;
                            n2 = n3;
                            consumed = 2;
                        }
                    }
                }
            }
            let out_len = batch * c2 * n2;
            if i + consumed < nlayers {
                act_len = act_len.max(out_len);
            }
            steps.push(Step {
                layer: i,
                kernel,
                op,
                in_len,
                out_len,
            });
            c = c2;
            n = n2;
            i += consumed;
        }
        Ok(Plan {
            batch,
            steps,
            n_layers: nlayers,
            act_len,
            tmp_len,
            col_len,
            fuse_len,
            in_len: batch * model.c_in * model.seq_len,
            out_c: c,
            out_n: n,
            tunes,
        })
    }

    /// The batch size this plan was compiled for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Total arena elements: `2·act + tmp + col + fuse`.
    pub fn arena_len(&self) -> usize {
        2 * self.act_len + self.tmp_len + self.col_len + self.fuse_len
    }

    /// The chosen kernel per *step* (fused steps appear once).
    pub fn kernels(&self) -> Vec<PlanKernel> {
        self.steps.iter().map(|s| s.kernel).collect()
    }

    /// The chosen kernel per *model layer*, expanding fused steps back
    /// to their constituent layers — the audit surface parity tests map
    /// onto eager per-layer backend overrides.
    pub fn layer_kernels(&self) -> Vec<PlanKernel> {
        let mut out = Vec::with_capacity(self.n_layers);
        for s in &self.steps {
            match s.kernel {
                PlanKernel::FusedSlidingPool => {
                    out.push(PlanKernel::Sliding);
                    out.push(PlanKernel::Pool);
                }
                k => out.push(k),
            }
        }
        out
    }

    /// Number of fused conv→pool steps in the plan.
    pub fn fused_steps(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| s.kernel == PlanKernel::FusedSlidingPool)
            .count()
    }

    /// Autotune audit log: one entry per probed (or cache-served)
    /// conv-shaped layer; empty for heuristic/fixed plans.
    pub fn tuning(&self) -> &[LayerTune] {
        &self.tunes
    }

    /// Human-readable per-layer choices, e.g.
    /// `conv(k=7,c8)→sliding | pool(max)→pool | dense(4)→gemm`; fused
    /// steps print both layers:
    /// `conv(k=7,c8)+pool(max,w=2)→sliding+pool`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .steps
            .iter()
            .map(|s| {
                let shape = match &s.op {
                    StepOp::Conv { p, .. } => format!("conv(k={},c{})", p.k, p.c_out),
                    StepOp::Residual { p } => format!("residual(k={},d={})", p.k, p.dilation),
                    StepOp::Pool { kind, p } => format!("pool({},w={})", kind.name(), p.w),
                    StepOp::Dense { out, .. } => format!("dense({out})"),
                    StepOp::ConvPool { conv, kind, pool, .. } => format!(
                        "conv(k={},c{})+pool({},w={})",
                        conv.k,
                        conv.c_out,
                        kind.name(),
                        pool.w
                    ),
                };
                format!("{shape}→{}", s.kernel.name())
            })
            .collect();
        parts.join(" | ")
    }

    /// Execute on the shared global executor. See
    /// [`Plan::run_with_into`].
    pub fn run_into(
        &self,
        model: &Model,
        x: &[f32],
        scratch: &mut PlanScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        self.run_with_into(Executor::global(), model, x, scratch, out)
    }

    /// Execute the plan: `x` is `[batch, c_in, seq_len]` flattened with
    /// exactly the compiled batch; `out` is resized to the output length
    /// once and fully overwritten. Returns the per-row `(channels, n)`.
    /// `model` must be the model the plan was compiled from (layer
    /// stack is cross-checked). Bit-identical to
    /// [`Model::forward_eager_into`] with the same backend choices.
    pub fn run_with_into(
        &self,
        ex: &Executor,
        model: &Model,
        x: &[f32],
        scratch: &mut PlanScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        ensure!(
            model.layer_count() == self.n_layers,
            "plan compiled for a different model (layer count {} vs {})",
            self.n_layers,
            model.layer_count()
        );
        ensure!(
            x.len() == self.in_len,
            "input length {} != planned batch {} × c_in × seq_len = {}",
            x.len(),
            self.batch,
            self.in_len
        );
        // Grow-only: plans for several batch buckets share one scratch
        // (every consumer takes region prefixes), so a smaller plan must
        // not shrink-then-regrow the arena on every bucket change.
        let arena_len = self.arena_len();
        if scratch.arena.len() < arena_len {
            scratch.arena.resize(arena_len, 0.0);
        }
        out.resize(self.batch * self.out_c * self.out_n, 0.0);
        let (reg_a, rest) = scratch.arena.split_at_mut(self.act_len);
        let (reg_b, rest) = rest.split_at_mut(self.act_len);
        let (tmp_reg, rest) = rest.split_at_mut(self.tmp_len);
        let (col_reg, fuse_reg) = rest.split_at_mut(self.col_len);
        // The activation regions alternate roles per step; the first
        // step reads the request input, the last writes `out`.
        let mut reg_src: &mut [f32] = reg_b;
        let mut reg_dst: &mut [f32] = reg_a;
        let last = self.steps.len() - 1;
        for (i, step) in self.steps.iter().enumerate() {
            {
                let src: &[f32] = if i == 0 { x } else { &reg_src[..step.in_len] };
                let dst: &mut [f32] = if i == last {
                    out.as_mut_slice()
                } else {
                    &mut reg_dst[..step.out_len]
                };
                exec_step(ex, model, step, src, dst, tmp_reg, col_reg, fuse_reg)?;
            }
            std::mem::swap(&mut reg_src, &mut reg_dst);
        }
        Ok((self.out_c, self.out_n))
    }
}

/// Run one compiled step. `src`/`dst` are the step's activation views
/// (disjoint by the arena layout); `tmp`/`col`/`fuse` are the shared
/// residual, im2col, and fused-row regions.
#[allow(clippy::too_many_arguments)]
fn exec_step(
    ex: &Executor,
    model: &Model,
    step: &Step,
    src: &[f32],
    dst: &mut [f32],
    tmp: &mut [f32],
    col: &mut [f32],
    fuse: &mut [f32],
) -> Result<()> {
    let layer = &model.layers()[step.layer];
    match (&step.op, layer) {
        (StepOp::Conv { p, relu }, Layer::Conv { w, b, .. }) => {
            let epi = if *relu { Epilogue::Relu } else { Epilogue::None };
            run_conv(ex, step.kernel, src, w, Some(b), p, epi, col, dst)
        }
        (StepOp::Residual { p }, Layer::Residual { w1, b1, w2, b2, .. }) => {
            let t = &mut tmp[..step.in_len];
            run_conv(ex, step.kernel, src, w1, Some(b1), p, Epilogue::Relu, col, t)?;
            run_conv(
                ex,
                step.kernel,
                &*t,
                w2,
                Some(b2),
                p,
                Epilogue::ReluAdd(src),
                col,
                dst,
            )
        }
        (StepOp::Pool { kind, p }, Layer::Pool { .. }) => {
            pool1d_with_into(ex, *kind, src, p, dst);
            Ok(())
        }
        (StepOp::Dense { feat, out, relu }, Layer::Dense { w, b, .. }) => {
            dense_forward(ex, src, w, b, step.in_len / feat, *feat, *out, *relu, dst);
            Ok(())
        }
        (
            StepOp::ConvPool {
                conv: cp,
                relu,
                kind,
                pool,
            },
            Layer::Conv { w, b, .. },
        ) => {
            let epi = if *relu { Epilogue::Relu } else { Epilogue::None };
            run_fused_conv_pool(ex, src, w, Some(b), cp, epi, *kind, pool, fuse, dst);
            Ok(())
        }
        _ => bail!(
            "plan step {} does not match the model's layer kind",
            step.layer
        ),
    }
}

/// Execute a fused conv→pool step: every `(batch, c_out)` conv row is
/// computed into a cache-resident row buffer from the arena's fuse
/// region (by the *same* per-row body the unfused sliding kernel runs —
/// [`conv::conv1d_sliding_row_into`]) and the non-overlapping pool
/// windows fold straight out of it (by the *same* fold the unfused pool
/// runs — [`pool1d_row_nonoverlap`]); the dense conv activation never
/// materializes. Workers own disjoint row buffers and write disjoint
/// pool-output row chunks, and per-row values do not depend on the
/// partitioning, so results are bit-identical to the two-step plan for
/// every thread count.
#[allow(clippy::too_many_arguments)]
fn run_fused_conv_pool(
    ex: &Executor,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    cp: &Conv1dParams,
    epi: Epilogue<'_>,
    kind: PoolKind,
    pp: &Pool1dParams,
    fuse: &mut [f32],
    dst: &mut [f32],
) {
    let n_conv = cp.n_out();
    let n_pool = pp.n_out();
    let rows = cp.batch * cp.c_out;
    debug_assert_eq!(dst.len(), rows * n_pool, "fused dst length");
    debug_assert_eq!(pp.n, n_conv, "pool reads the conv row");
    let tasks = rows.min(FUSE_MAX_TASKS);
    let fuse = &mut fuse[..tasks * n_conv];
    if ex.threads() <= 1 || tasks <= 1 || rows * n_conv < PAR_MIN_FANOUT {
        let buf = &mut fuse[..n_conv];
        for (r, drow) in dst.chunks_mut(n_pool).enumerate() {
            conv::conv1d_sliding_row_into(buf, r, x, w, bias, cp, epi);
            pool1d_row_nonoverlap(kind, buf, pp, drow);
        }
        return;
    }
    // Balanced contiguous row chunks: every one of the `tasks` row
    // buffers gets a job, with chunk sizes differing by at most one row
    // (`ceil(remaining / tasks_left)` per step), so e.g. 18 rows over
    // 16 buffers run as 16 jobs of 1–2 rows, not 9 jobs of 2.
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(tasks);
    let mut rest = dst;
    let mut bufs = fuse.chunks_mut(n_conv);
    let mut r0 = 0usize;
    for ti in 0..tasks {
        let take = (rows - r0).div_ceil(tasks - ti);
        // Move the remainder out of the loop variable so the split's
        // halves inherit the full arena lifetime.
        let rem = rest;
        let (dchunk, tail) = rem.split_at_mut(take * n_pool);
        rest = tail;
        let buf = bufs.next().expect("one row buffer per task");
        jobs.push(Box::new(move || {
            for (j, drow) in dchunk.chunks_mut(n_pool).enumerate() {
                conv::conv1d_sliding_row_into(buf, r0 + j, x, w, bias, cp, epi);
                pool1d_row_nonoverlap(kind, buf, pp, drow);
            }
        }));
        r0 += take;
    }
    ex.scope(jobs);
}

/// Dispatch a conv-shaped step to its chosen kernel, epilogue fused.
#[allow(clippy::too_many_arguments)]
fn run_conv(
    ex: &Executor,
    kernel: PlanKernel,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    col: &mut [f32],
    y: &mut [f32],
) -> Result<()> {
    match kernel {
        PlanKernel::Sliding => conv::conv1d_sliding_with_into(ex, x, w, bias, p, epi, y),
        PlanKernel::Im2col => conv::conv1d_im2col_epilogue_into(ex, x, w, bias, p, epi, col, y),
        PlanKernel::SmallK => {
            ensure!(
                conv::conv1d_small_k_into(x, w, bias, p, epi, y),
                "planner selected small_k for a non-qualifying shape"
            );
        }
        PlanKernel::Direct => {
            conv::conv1d_direct_into(x, w, bias, p, y);
            epi.apply(y, 0);
        }
        PlanKernel::SlidingPair => {
            let v = conv::conv1d_pair(x, w, bias, p);
            y.copy_from_slice(&v);
            epi.apply(y, 0);
        }
        PlanKernel::Gemm | PlanKernel::Pool | PlanKernel::FusedSlidingPool => {
            bail!("non-conv kernel {} in a conv step", kernel.name())
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::load_config;
    use crate::workload::Rng;

    const CFG: &str = r#"
[model]
name = "plan_t"
c_in = 1
seq_len = 64

[layer.0]
type = "conv"
c_out = 8
k = 7

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "dense"
out = 3
"#;

    fn model() -> Model {
        let (mc, _) = load_config(CFG).unwrap();
        Model::init(&mc, &mut Rng::new(7)).unwrap()
    }

    #[test]
    fn compile_resolves_every_layer() {
        let m = model();
        let plan = Plan::compile(&m, 4, &PlannerConfig::default()).unwrap();
        assert_eq!(plan.batch(), 4);
        // The pool follows a residual, not a conv, so nothing fuses.
        assert_eq!(plan.kernels().len(), 4);
        assert_eq!(plan.fused_steps(), 0);
        assert_eq!(plan.kernels()[2], PlanKernel::Pool);
        assert_eq!(plan.kernels()[3], PlanKernel::Gemm);
        assert_eq!(plan.layer_kernels(), plan.kernels());
        assert!(plan.arena_len() > 0);
        assert!(plan.describe().contains("dense(3)→gemm"), "{}", plan.describe());
    }

    #[test]
    fn fixed_backend_maps_every_conv_layer() {
        let m = model();
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Im2colGemm),
            ..PlannerConfig::default()
        };
        let plan = Plan::compile(&m, 1, &cfg).unwrap();
        assert_eq!(plan.kernels()[0], PlanKernel::Im2col);
        assert_eq!(plan.kernels()[1], PlanKernel::Im2col);
        assert!(plan.col_len > 0, "im2col layers reserve a column region");
    }

    #[test]
    fn planned_run_matches_forward() {
        let m = model();
        let mut rng = Rng::new(9);
        for batch in [1usize, 3] {
            let x = rng.vec_uniform(batch * 64, -1.0, 1.0);
            let want = m.forward(&x, batch, ConvBackend::Sliding).unwrap();
            let cfg = PlannerConfig {
                backend: BackendChoice::Fixed(ConvBackend::Sliding),
                ..PlannerConfig::default()
            };
            let plan = Plan::compile(&m, batch, &cfg).unwrap();
            let mut scratch = PlanScratch::default();
            let mut out = Vec::new();
            let (c, n) = plan.run_into(&m, &x, &mut scratch, &mut out).unwrap();
            assert_eq!((c, n), m.out_shape());
            assert_eq!(out, want.data, "batch {batch}");
        }
    }

    #[test]
    fn wrong_batch_rejected() {
        let m = model();
        let plan = Plan::compile(&m, 2, &PlannerConfig::default()).unwrap();
        let mut scratch = PlanScratch::default();
        let mut out = Vec::new();
        assert!(plan.run_into(&m, &[0.0; 64], &mut scratch, &mut out).is_err());
    }

    #[test]
    fn cost_model_prefers_small_k_and_sliding() {
        // Single-channel k=3 → small_k.
        let p = Conv1dParams::new(1, 1, 1024, 3);
        assert_eq!(choose_kernel(&p), PlanKernel::SmallK);
        // Large filter → sliding.
        let p = Conv1dParams::new(1, 1, 1024, 63);
        assert_eq!(choose_kernel(&p), PlanKernel::Sliding);
        // Fat channel reduction with a tiny receptive field → im2col.
        let p = Conv1dParams::new(16, 32, 1024, 3).with_same_pad();
        assert_eq!(choose_kernel(&p), PlanKernel::Im2col);
        // Same reduction but dilated far → sliding again.
        let p = Conv1dParams::new(16, 32, 1024, 3).with_dilation(8).with_same_pad();
        assert_eq!(choose_kernel(&p), PlanKernel::Sliding);
    }

    /// Pin every decision boundary of the shape heuristic so the
    /// autotuner can evolve without silently shifting the probe-free
    /// fallback (`c_out ≥ 8`, `c_in·k ≥ 48`, `eff_k ≤ 9`, small-k
    /// qualification).
    #[test]
    fn choose_kernel_decision_boundaries_pinned() {
        let base = |c_in: usize, c_out: usize, k: usize| Conv1dParams::new(c_in, c_out, 4096, k);
        // c_in·k = 48 exactly, c_out = 8 exactly, eff_k = 3 → im2col.
        assert_eq!(choose_kernel(&base(16, 8, 3)), PlanKernel::Im2col);
        // One below the c_out boundary.
        assert_eq!(choose_kernel(&base(16, 7, 3)), PlanKernel::Sliding);
        // One below the reduction boundary (45 < 48).
        assert_eq!(choose_kernel(&base(15, 8, 3)), PlanKernel::Sliding);
        // eff_k = 9 exactly still qualifies (6·9 = 54 ≥ 48).
        assert_eq!(choose_kernel(&base(6, 8, 9)), PlanKernel::Im2col);
        // eff_k = 10 does not.
        assert_eq!(choose_kernel(&base(6, 8, 10)), PlanKernel::Sliding);
        // Dilation pushes the receptive field over the boundary:
        // (3−1)·4+1 = 9 qualifies, (3−1)·5+1 = 11 does not.
        assert_eq!(
            choose_kernel(&base(16, 8, 3).with_dilation(4)),
            PlanKernel::Im2col
        );
        assert_eq!(
            choose_kernel(&base(16, 8, 3).with_dilation(5)),
            PlanKernel::Sliding
        );
        // Small-k qualification: single channel, unit stride/dilation,
        // no padding, k ∈ {3, 5}.
        assert_eq!(choose_kernel(&base(1, 1, 5)), PlanKernel::SmallK);
        assert_eq!(choose_kernel(&base(1, 1, 7)), PlanKernel::Sliding);
        assert_eq!(
            choose_kernel(&base(1, 1, 3).with_stride(2)),
            PlanKernel::Sliding
        );
        assert_eq!(
            choose_kernel(&base(1, 1, 3).with_dilation(2)),
            PlanKernel::Sliding
        );
        assert_eq!(
            choose_kernel(&base(1, 1, 3).with_same_pad()),
            PlanKernel::Sliding
        );
        assert_eq!(choose_kernel(&base(2, 1, 3)), PlanKernel::Sliding);
    }

    #[test]
    fn conv_pool_fusion_fuses_nonoverlapping_only() {
        const FUSE_CFG: &str = r#"
[model]
name = "fuse_t"
c_in = 1
seq_len = 96

[layer.0]
type = "conv"
c_out = 4
k = 5

[layer.1]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.2]
type = "conv"
c_out = 4
k = 3

[layer.3]
type = "pool"
kind = "avg"
w = 3
stride = 2
"#;
        let (mc, _) = load_config(FUSE_CFG).unwrap();
        let m = Model::init(&mc, &mut Rng::new(5)).unwrap();
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Sliding),
            ..PlannerConfig::default()
        };
        let plan = Plan::compile(&m, 2, &cfg).unwrap();
        // Layer 0+1 fuse (stride ≥ w); layer 2+3 must not (overlapping
        // windows, stride < w, go through the dense sliding pass).
        assert_eq!(plan.fused_steps(), 1, "{}", plan.describe());
        assert_eq!(
            plan.kernels(),
            vec![
                PlanKernel::FusedSlidingPool,
                PlanKernel::Sliding,
                PlanKernel::Pool
            ],
            "{}",
            plan.describe()
        );
        assert_eq!(
            plan.layer_kernels(),
            vec![
                PlanKernel::Sliding,
                PlanKernel::Pool,
                PlanKernel::Sliding,
                PlanKernel::Pool
            ]
        );
        assert!(plan.fuse_len > 0, "fused step reserves row buffers");
        assert!(plan.describe().contains("+pool(max,w=2)→sliding+pool"), "{}", plan.describe());

        // Fusion off → one step per layer, no fuse region.
        let unfused = Plan::compile(
            &m,
            2,
            &PlannerConfig {
                fuse: false,
                ..cfg
            },
        )
        .unwrap();
        assert_eq!(unfused.fused_steps(), 0);
        assert_eq!(unfused.kernels().len(), 4);
        assert_eq!(unfused.fuse_len, 0);

        // Fused and unfused runs are bit-identical (and match eager).
        let mut rng = Rng::new(11);
        let x = rng.vec_uniform(2 * 96, -1.0, 1.0);
        let mut scratch = PlanScratch::default();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        plan.run_into(&m, &x, &mut scratch, &mut a).unwrap();
        unfused.run_into(&m, &x, &mut scratch, &mut b).unwrap();
        assert_eq!(a, b, "fused plan diverged from unfused plan");
        let want = m.forward(&x, 2, ConvBackend::Sliding).unwrap();
        assert_eq!(a, want.data, "fused plan diverged from forward");
    }

    #[test]
    fn fixed_non_sliding_backends_do_not_fuse() {
        const CFG2: &str = r#"
[model]
name = "nofuse"
c_in = 1
seq_len = 64

[layer.0]
type = "conv"
c_out = 4
k = 3

[layer.1]
type = "pool"
kind = "max"
w = 2
stride = 2
"#;
        let (mc, _) = load_config(CFG2).unwrap();
        let m = Model::init(&mc, &mut Rng::new(3)).unwrap();
        for backend in [ConvBackend::Im2colGemm, ConvBackend::Direct] {
            let plan = Plan::compile(
                &m,
                1,
                &PlannerConfig {
                    backend: BackendChoice::Fixed(backend),
                    ..PlannerConfig::default()
                },
            )
            .unwrap();
            assert_eq!(plan.fused_steps(), 0, "{backend:?}");
            assert_eq!(plan.kernels().len(), 2, "{backend:?}");
        }
    }

    #[test]
    fn autotune_records_probes_and_hits_cache_on_recompile() {
        let m = model();
        let cfg = PlannerConfig {
            backend: BackendChoice::Auto,
            autotune: true,
            ..PlannerConfig::default()
        };
        // Uncommon batch so other tests cannot have pre-seeded the keys.
        let plan = Plan::compile(&m, 6, &cfg).unwrap();
        // Two conv-shaped layers (conv + residual) → two tune records.
        assert_eq!(plan.tuning().len(), 2);
        for t in plan.tuning() {
            if !t.cached {
                assert!(
                    t.probes.len() >= 3,
                    "probes cover sliding/im2col/direct at least: {t:?}"
                );
                assert!(t.probes.iter().any(|p| p.kernel == t.chosen));
                assert!(t.probes.iter().all(|p| p.micros.is_finite()));
            }
        }
        // Recompiling the same shapes is served from the TuneCache.
        let again = Plan::compile(&m, 6, &cfg).unwrap();
        assert!(
            again.tuning().iter().all(|t| t.cached),
            "second compile re-probed: {:?}",
            again.tuning()
        );
        assert_eq!(
            plan.tuning().iter().map(|t| t.chosen).collect::<Vec<_>>(),
            again.tuning().iter().map(|t| t.chosen).collect::<Vec<_>>(),
            "cache returned a different decision"
        );
        // Autotuned plans execute like any other plan.
        let mut rng = Rng::new(13);
        let x = rng.vec_uniform(6 * 64, -1.0, 1.0);
        let mut out = Vec::new();
        plan.run_into(&m, &x, &mut PlanScratch::default(), &mut out)
            .unwrap();
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn per_layer_override_bypasses_autotune() {
        const CFG3: &str = r#"
[model]
name = "pinned"
c_in = 1
seq_len = 48

[layer.0]
type = "conv"
c_out = 4
k = 5
backend = "direct"
"#;
        let (mc, _) = load_config(CFG3).unwrap();
        let m = Model::init(&mc, &mut Rng::new(2)).unwrap();
        let plan = Plan::compile(
            &m,
            1,
            &PlannerConfig {
                backend: BackendChoice::Auto,
                autotune: true,
                ..PlannerConfig::default()
            },
        )
        .unwrap();
        assert_eq!(plan.kernels(), vec![PlanKernel::Direct]);
        assert!(plan.tuning().is_empty(), "override must not probe");
    }
}
