//! Compile-once execution plans for the layer stack.
//!
//! [`Plan::compile`] runs once per `(model, batch-bucket, backend
//! choice)` and bakes every per-request decision out of the hot path:
//!
//! * **Shape resolution** — every layer's [`Conv1dParams`] /
//!   [`Pool1dParams`] (with the batch folded in) is derived ahead of
//!   time; execution never re-derives a shape.
//! * **Per-layer kernel selection** — each conv-bearing layer gets a
//!   [`PlanKernel`] from, in priority order: the layer's `backend =`
//!   override in the model TOML, the deployment-level
//!   [`BackendChoice::Fixed`] backend, or (under
//!   [`BackendChoice::Auto`]) the shape-based cost model in
//!   [`choose_kernel`]. The paper's crossover (sliding wins at large
//!   filters, GEMM at small filters with fat channel reductions) is
//!   what the cost model encodes; the `eager_vs_planned` bench prints
//!   the chosen kernels next to throughput so the model stays auditable.
//! * **Arena layout** — one flat `Vec<f32>` holds every intermediate:
//!   `[ act A | act B | residual tmp | im2col col ]`, with region sizes
//!   (`act_len`, `tmp_len`, `col_len`) precomputed at compile time.
//!   Step *i* reads one activation region and writes the other
//!   (alternating; step 0 reads the request input, the last step writes
//!   the caller's output buffer), so execution does no resizing, no
//!   ping/pong `Vec` swaps, and — for all kernels except the
//!   faithful-math `SlidingPair` — no allocation at all after warm-up.
//! * **Fused epilogues** — bias is already part of the kernels'
//!   accumulator seed; the ReLU tail and the residual skip-add ride the
//!   kernels' destination writes as an [`Epilogue`] instead of separate
//!   memory passes.
//!
//! [`Plan::run_into`] is bit-identical to the eager reference path
//! ([`Model::forward_eager_into`]) for every fixed backend, thread
//! count, and SIMD tier — enforced by `tests/plan_parity.rs`. The
//! serving engines compile and cache plans keyed by batch size
//! ([`crate::coordinator::NativeEngine`]); the eager
//! [`Model::forward_into`] is itself a compile-then-run wrapper.

use anyhow::{bail, ensure, Result};

use crate::conv::{self, BackendChoice, Conv1dParams, ConvBackend};
use crate::exec::Executor;
use crate::ops::Epilogue;
use crate::pool::{pool1d_with_into, Pool1dParams, PoolKind};

use super::layers::{dense_forward, Layer};
use super::Model;

/// Which kernel executes a planned layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanKernel {
    /// Broadcast-FMA sliding-window conv (the paper's contribution).
    Sliding,
    /// im2col + blocked GEMM, column matrix in the plan arena.
    Im2col,
    /// Fused register-blocked small-filter kernel (k ∈ {3, 5}).
    SmallK,
    /// Nested-loop reference conv.
    Direct,
    /// Literal Eq. 7–9 pair-operator prefix sum (allocates; kept for
    /// fidelity, never chosen by the cost model).
    SlidingPair,
    /// Blocked-GEMM gemv (dense layers).
    Gemm,
    /// Sliding-sum pooling.
    Pool,
}

impl PlanKernel {
    pub fn name(&self) -> &'static str {
        match self {
            PlanKernel::Sliding => "sliding",
            PlanKernel::Im2col => "im2col",
            PlanKernel::SmallK => "small_k",
            PlanKernel::Direct => "direct",
            PlanKernel::SlidingPair => "sliding_pair",
            PlanKernel::Gemm => "gemm",
            PlanKernel::Pool => "pool",
        }
    }
}

/// Planner inputs beyond the model itself.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlannerConfig {
    /// Deployment-level backend selection (`--backend` /
    /// `serve.backend`); per-layer TOML overrides beat it either way.
    pub backend: BackendChoice,
}

/// One compiled layer step: resolved shapes + chosen kernel. The arena
/// region a step reads/writes follows its position (alternating A/B;
/// first reads the input, last writes the output), so the step itself
/// only carries lengths.
#[derive(Clone, Debug)]
struct Step {
    /// Index into the model's layer stack (weight lookup + validation).
    layer: usize,
    kernel: PlanKernel,
    op: StepOp,
    /// Input elements (`batch · c · n`).
    in_len: usize,
    /// Output elements (`batch · c2 · n2`).
    out_len: usize,
}

#[derive(Clone, Debug)]
enum StepOp {
    Conv { p: Conv1dParams, relu: bool },
    Residual { p: Conv1dParams },
    Pool { kind: PoolKind, p: Pool1dParams },
    Dense { feat: usize, out: usize, relu: bool },
}

/// The scratch a plan executes in: one flat arena
/// `[act A | act B | tmp | col]`, grown once to the plan's precomputed
/// size and recycled dirty across requests.
#[derive(Clone, Debug, Default)]
pub struct PlanScratch {
    arena: Vec<f32>,
}

/// Keyed compile-once plan cache (tiny linear scan — one entry per
/// batch bucket / backend pair). Shared by
/// [`crate::coordinator::NativeEngine`] (keyed by batch size) and
/// [`super::ForwardScratch`](crate::nn::ForwardScratch) (keyed by
/// batch + backend).
#[derive(Clone, Debug)]
pub struct PlanCache<K> {
    entries: Vec<(K, Plan)>,
}

impl<K> Default for PlanCache<K> {
    fn default() -> Self {
        Self { entries: Vec::new() }
    }
}

impl<K: PartialEq + Copy> PlanCache<K> {
    /// The cached plan for `key`, compiling (and caching) on first use.
    pub fn get_or_compile(
        &mut self,
        key: K,
        compile: impl FnOnce() -> Result<Plan>,
    ) -> Result<&Plan> {
        let idx = match self.entries.iter().position(|(k, _)| *k == key) {
            Some(i) => i,
            None => {
                self.entries.push((key, compile()?));
                self.entries.len() - 1
            }
        };
        Ok(&self.entries[idx].1)
    }

    /// Number of compiled plans cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// A compiled execution plan for one `(model, batch)` pair. Cheap to
/// clone (no parameter copies — weights stay in the [`Model`] the plan
/// is run against).
#[derive(Clone, Debug)]
pub struct Plan {
    batch: usize,
    steps: Vec<Step>,
    /// Elements per activation ping/pong region (max intermediate).
    act_len: usize,
    /// Elements for the residual intermediate region.
    tmp_len: usize,
    /// Elements for the im2col column region (largest im2col layer).
    col_len: usize,
    in_len: usize,
    out_c: usize,
    out_n: usize,
}

/// Shape-based kernel choice for a conv-shaped layer under `Auto`.
///
/// The heuristic mirrors the paper's Fig-1 crossover plus the §5
/// small-filter note:
/// * the fused small-k kernel when it applies (single channel, unit
///   stride/dilation, k ∈ {3, 5} — highest arithmetic intensity per
///   load of all paths);
/// * im2col + GEMM when the channel reduction is fat enough to feed the
///   8×8 microkernel (`c_out ≥ 8`, `c_in·k ≥ 48`) **and** the receptive
///   field is small (`effective_k ≤ 9`) — there the sliding schedule
///   degenerates to a few short passes while the k× expansion stays
///   cheap;
/// * the sliding kernel everywhere else (large filters, thin channel
///   counts, dilated stacks — the shapes the paper shows it winning).
pub fn choose_kernel(p: &Conv1dParams) -> PlanKernel {
    if conv::small_k_qualifies(p) {
        PlanKernel::SmallK
    } else if p.c_out >= 8 && p.c_in * p.k >= 48 && p.effective_k() <= 9 {
        PlanKernel::Im2col
    } else {
        PlanKernel::Sliding
    }
}

fn kernel_for_backend(b: ConvBackend) -> PlanKernel {
    match b {
        ConvBackend::Sliding => PlanKernel::Sliding,
        ConvBackend::Im2colGemm => PlanKernel::Im2col,
        ConvBackend::Direct => PlanKernel::Direct,
        ConvBackend::SlidingPair => PlanKernel::SlidingPair,
    }
}

impl Plan {
    /// Compile the model for one batch size. Runs once per batch bucket;
    /// everything shape- or choice-dependent happens here.
    pub fn compile(model: &Model, batch: usize, cfg: &PlannerConfig) -> Result<Plan> {
        ensure!(batch >= 1, "plan batch must be >= 1");
        ensure!(
            model.layer_count() > 0,
            "cannot compile a plan for an empty model"
        );
        let nlayers = model.layer_count();
        let (mut c, mut n) = (model.c_in, model.seq_len);
        let mut steps = Vec::with_capacity(nlayers);
        let (mut act_len, mut tmp_len, mut col_len) = (0usize, 0usize, 0usize);
        for (i, layer) in model.layers().iter().enumerate() {
            let in_len = batch * c * n;
            // Priority: per-layer TOML override > fixed deployment
            // backend > cost model.
            let pick = |p: &Conv1dParams| match model.backend_override(i) {
                Some(b) => kernel_for_backend(b),
                None => match cfg.backend {
                    BackendChoice::Fixed(b) => kernel_for_backend(b),
                    BackendChoice::Auto => choose_kernel(p),
                },
            };
            let (kernel, op) = match layer {
                Layer::Conv {
                    c_in,
                    c_out,
                    k,
                    stride,
                    dilation,
                    same_pad,
                    relu,
                    ..
                } => {
                    ensure!(c == *c_in, "layer {i}: conv input channels");
                    let mut p = Conv1dParams::new(*c_in, *c_out, n, *k)
                        .with_batch(batch)
                        .with_stride(*stride)
                        .with_dilation(*dilation);
                    if *same_pad {
                        p = p.with_same_pad();
                    }
                    let kernel = pick(&p);
                    if kernel == PlanKernel::Im2col {
                        col_len = col_len.max(p.c_in * p.k * p.n_out());
                    }
                    (kernel, StepOp::Conv { p, relu: *relu })
                }
                Layer::Residual { c: cr, k, dilation, .. } => {
                    ensure!(c == *cr, "layer {i}: residual channels");
                    let p = Conv1dParams::new(*cr, *cr, n, *k)
                        .with_batch(batch)
                        .with_dilation(*dilation)
                        .with_same_pad();
                    let kernel = pick(&p);
                    if kernel == PlanKernel::Im2col {
                        col_len = col_len.max(p.c_in * p.k * p.n_out());
                    }
                    tmp_len = tmp_len.max(in_len);
                    (kernel, StepOp::Residual { p })
                }
                Layer::Pool { kind, w, stride } => {
                    let p = Pool1dParams::new(c, n, *w).with_batch(batch).with_stride(*stride);
                    (PlanKernel::Pool, StepOp::Pool { kind: *kind, p })
                }
                Layer::Dense {
                    in_features,
                    out,
                    relu,
                    ..
                } => {
                    ensure!(c * n == *in_features, "layer {i}: dense input features");
                    (
                        PlanKernel::Gemm,
                        StepOp::Dense {
                            feat: *in_features,
                            out: *out,
                            relu: *relu,
                        },
                    )
                }
            };
            let (c2, n2) = layer.out_shape(c, n);
            ensure!(n2 > 0, "layer {i} produces empty output (c={c}, n={n})");
            let out_len = batch * c2 * n2;
            if i + 1 < nlayers {
                act_len = act_len.max(out_len);
            }
            steps.push(Step {
                layer: i,
                kernel,
                op,
                in_len,
                out_len,
            });
            c = c2;
            n = n2;
        }
        Ok(Plan {
            batch,
            steps,
            act_len,
            tmp_len,
            col_len,
            in_len: batch * model.c_in * model.seq_len,
            out_c: c,
            out_n: n,
        })
    }

    /// The batch size this plan was compiled for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Total arena elements: `2·act + tmp + col`.
    pub fn arena_len(&self) -> usize {
        2 * self.act_len + self.tmp_len + self.col_len
    }

    /// The chosen kernel per layer (cost-model audit surface).
    pub fn kernels(&self) -> Vec<PlanKernel> {
        self.steps.iter().map(|s| s.kernel).collect()
    }

    /// Human-readable per-layer choices, e.g.
    /// `conv(k=7,c8)→sliding | pool(max)→pool | dense(4)→gemm`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .steps
            .iter()
            .map(|s| {
                let shape = match &s.op {
                    StepOp::Conv { p, .. } => format!("conv(k={},c{})", p.k, p.c_out),
                    StepOp::Residual { p } => format!("residual(k={},d={})", p.k, p.dilation),
                    StepOp::Pool { kind, p } => format!("pool({},w={})", kind.name(), p.w),
                    StepOp::Dense { out, .. } => format!("dense({out})"),
                };
                format!("{shape}→{}", s.kernel.name())
            })
            .collect();
        parts.join(" | ")
    }

    /// Execute on the shared global executor. See
    /// [`Plan::run_with_into`].
    pub fn run_into(
        &self,
        model: &Model,
        x: &[f32],
        scratch: &mut PlanScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        self.run_with_into(Executor::global(), model, x, scratch, out)
    }

    /// Execute the plan: `x` is `[batch, c_in, seq_len]` flattened with
    /// exactly the compiled batch; `out` is resized to the output length
    /// once and fully overwritten. Returns the per-row `(channels, n)`.
    /// `model` must be the model the plan was compiled from (layer
    /// stack is cross-checked). Bit-identical to
    /// [`Model::forward_eager_into`] with the same backend choices.
    pub fn run_with_into(
        &self,
        ex: &Executor,
        model: &Model,
        x: &[f32],
        scratch: &mut PlanScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        ensure!(
            model.layer_count() == self.steps.len(),
            "plan compiled for a different model (layer count {} vs {})",
            self.steps.len(),
            model.layer_count()
        );
        ensure!(
            x.len() == self.in_len,
            "input length {} != planned batch {} × c_in × seq_len = {}",
            x.len(),
            self.batch,
            self.in_len
        );
        // Grow-only: plans for several batch buckets share one scratch
        // (every consumer takes region prefixes), so a smaller plan must
        // not shrink-then-regrow the arena on every bucket change.
        let arena_len = self.arena_len();
        if scratch.arena.len() < arena_len {
            scratch.arena.resize(arena_len, 0.0);
        }
        out.resize(self.batch * self.out_c * self.out_n, 0.0);
        let (reg_a, rest) = scratch.arena.split_at_mut(self.act_len);
        let (reg_b, rest) = rest.split_at_mut(self.act_len);
        let (tmp_reg, col_reg) = rest.split_at_mut(self.tmp_len);
        // The activation regions alternate roles per step; the first
        // step reads the request input, the last writes `out`.
        let mut reg_src: &mut [f32] = reg_b;
        let mut reg_dst: &mut [f32] = reg_a;
        let last = self.steps.len() - 1;
        for (i, step) in self.steps.iter().enumerate() {
            {
                let src: &[f32] = if i == 0 { x } else { &reg_src[..step.in_len] };
                let dst: &mut [f32] = if i == last {
                    out.as_mut_slice()
                } else {
                    &mut reg_dst[..step.out_len]
                };
                exec_step(ex, model, step, src, dst, tmp_reg, col_reg)?;
            }
            std::mem::swap(&mut reg_src, &mut reg_dst);
        }
        Ok((self.out_c, self.out_n))
    }
}

/// Run one compiled step. `src`/`dst` are the step's activation views
/// (disjoint by the arena layout); `tmp`/`col` are the shared residual
/// and im2col regions.
fn exec_step(
    ex: &Executor,
    model: &Model,
    step: &Step,
    src: &[f32],
    dst: &mut [f32],
    tmp: &mut [f32],
    col: &mut [f32],
) -> Result<()> {
    let layer = &model.layers()[step.layer];
    match (&step.op, layer) {
        (StepOp::Conv { p, relu }, Layer::Conv { w, b, .. }) => {
            let epi = if *relu { Epilogue::Relu } else { Epilogue::None };
            run_conv(ex, step.kernel, src, w, Some(b), p, epi, col, dst)
        }
        (StepOp::Residual { p }, Layer::Residual { w1, b1, w2, b2, .. }) => {
            let t = &mut tmp[..step.in_len];
            run_conv(ex, step.kernel, src, w1, Some(b1), p, Epilogue::Relu, col, t)?;
            run_conv(
                ex,
                step.kernel,
                &*t,
                w2,
                Some(b2),
                p,
                Epilogue::ReluAdd(src),
                col,
                dst,
            )
        }
        (StepOp::Pool { kind, p }, Layer::Pool { .. }) => {
            pool1d_with_into(ex, *kind, src, p, dst);
            Ok(())
        }
        (StepOp::Dense { feat, out, relu }, Layer::Dense { w, b, .. }) => {
            dense_forward(ex, src, w, b, step.in_len / feat, *feat, *out, *relu, dst);
            Ok(())
        }
        _ => bail!(
            "plan step {} does not match the model's layer kind",
            step.layer
        ),
    }
}

/// Dispatch a conv-shaped step to its chosen kernel, epilogue fused.
#[allow(clippy::too_many_arguments)]
fn run_conv(
    ex: &Executor,
    kernel: PlanKernel,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    col: &mut [f32],
    y: &mut [f32],
) -> Result<()> {
    match kernel {
        PlanKernel::Sliding => conv::conv1d_sliding_with_into(ex, x, w, bias, p, epi, y),
        PlanKernel::Im2col => conv::conv1d_im2col_epilogue_into(ex, x, w, bias, p, epi, col, y),
        PlanKernel::SmallK => {
            ensure!(
                conv::conv1d_small_k_into(x, w, bias, p, epi, y),
                "planner selected small_k for a non-qualifying shape"
            );
        }
        PlanKernel::Direct => {
            conv::conv1d_direct_into(x, w, bias, p, y);
            epi.apply(y, 0);
        }
        PlanKernel::SlidingPair => {
            let v = conv::conv1d_pair(x, w, bias, p);
            y.copy_from_slice(&v);
            epi.apply(y, 0);
        }
        PlanKernel::Gemm | PlanKernel::Pool => {
            bail!("non-conv kernel {} in a conv step", kernel.name())
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::load_config;
    use crate::workload::Rng;

    const CFG: &str = r#"
[model]
name = "plan_t"
c_in = 1
seq_len = 64

[layer.0]
type = "conv"
c_out = 8
k = 7

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "dense"
out = 3
"#;

    fn model() -> Model {
        let (mc, _) = load_config(CFG).unwrap();
        Model::init(&mc, &mut Rng::new(7)).unwrap()
    }

    #[test]
    fn compile_resolves_every_layer() {
        let m = model();
        let plan = Plan::compile(&m, 4, &PlannerConfig::default()).unwrap();
        assert_eq!(plan.batch(), 4);
        assert_eq!(plan.kernels().len(), 4);
        assert_eq!(plan.kernels()[2], PlanKernel::Pool);
        assert_eq!(plan.kernels()[3], PlanKernel::Gemm);
        assert!(plan.arena_len() > 0);
        assert!(plan.describe().contains("dense(3)→gemm"), "{}", plan.describe());
    }

    #[test]
    fn fixed_backend_maps_every_conv_layer() {
        let m = model();
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Im2colGemm),
        };
        let plan = Plan::compile(&m, 1, &cfg).unwrap();
        assert_eq!(plan.kernels()[0], PlanKernel::Im2col);
        assert_eq!(plan.kernels()[1], PlanKernel::Im2col);
        assert!(plan.col_len > 0, "im2col layers reserve a column region");
    }

    #[test]
    fn planned_run_matches_forward() {
        let m = model();
        let mut rng = Rng::new(9);
        for batch in [1usize, 3] {
            let x = rng.vec_uniform(batch * 64, -1.0, 1.0);
            let want = m.forward(&x, batch, ConvBackend::Sliding).unwrap();
            let cfg = PlannerConfig {
                backend: BackendChoice::Fixed(ConvBackend::Sliding),
            };
            let plan = Plan::compile(&m, batch, &cfg).unwrap();
            let mut scratch = PlanScratch::default();
            let mut out = Vec::new();
            let (c, n) = plan.run_into(&m, &x, &mut scratch, &mut out).unwrap();
            assert_eq!((c, n), m.out_shape());
            assert_eq!(out, want.data, "batch {batch}");
        }
    }

    #[test]
    fn wrong_batch_rejected() {
        let m = model();
        let plan = Plan::compile(&m, 2, &PlannerConfig::default()).unwrap();
        let mut scratch = PlanScratch::default();
        let mut out = Vec::new();
        assert!(plan.run_into(&m, &[0.0; 64], &mut scratch, &mut out).is_err());
    }

    #[test]
    fn cost_model_prefers_small_k_and_sliding() {
        // Single-channel k=3 → small_k.
        let p = Conv1dParams::new(1, 1, 1024, 3);
        assert_eq!(choose_kernel(&p), PlanKernel::SmallK);
        // Large filter → sliding.
        let p = Conv1dParams::new(1, 1, 1024, 63);
        assert_eq!(choose_kernel(&p), PlanKernel::Sliding);
        // Fat channel reduction with a tiny receptive field → im2col.
        let p = Conv1dParams::new(16, 32, 1024, 3).with_same_pad();
        assert_eq!(choose_kernel(&p), PlanKernel::Im2col);
        // Same reduction but dilated far → sliding again.
        let p = Conv1dParams::new(16, 32, 1024, 3).with_dilation(8).with_same_pad();
        assert_eq!(choose_kernel(&p), PlanKernel::Sliding);
    }
}
