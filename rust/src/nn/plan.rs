//! Compile-once execution plans for the layer stack.
//!
//! [`Plan::compile`] runs once per `(model, batch-bucket, backend
//! choice)` and bakes every per-request decision out of the hot path:
//!
//! * **Shape resolution** — every layer's [`Conv1dParams`] /
//!   [`Pool1dParams`] (with the batch folded in) is derived ahead of
//!   time; execution never re-derives a shape.
//! * **Per-layer kernel selection** — each conv-bearing layer gets a
//!   [`PlanKernel`] from, in priority order: the layer's `backend =`
//!   override in the model TOML, the deployment-level
//!   [`BackendChoice::Fixed`] backend, or (under
//!   [`BackendChoice::Auto`]) either the shape-based cost model in
//!   [`choose_kernel`] or — when [`PlannerConfig::autotune`] is set —
//!   a **measured** choice: compile micro-probes every candidate kernel
//!   (sliding / small_k / im2col+GEMM / direct) against the layer's
//!   real shape and weights and picks the fastest, caching the result
//!   in the process-wide [`TuneCache`] keyed by `(layer shape, SIMD
//!   tier, executor threads)` so repeated compiles are free. The shape
//!   heuristic was hand-fit to one machine; the probe makes the
//!   crossover (sliding wins at large filters, GEMM at small filters
//!   with fat channel reductions) portable across microarchitectures.
//! * **Chain fusion** — the planner greedily groups every maximal run
//!   of chain-eligible layers (sliding-kernel convs and interleaved
//!   non-overlapping valid-mode pools) into one [`FusedChain`] step.
//!   At run time, workers sweep `(batch element × final-column span)`
//!   tiles through the *entire* segment: each stage writes its output
//!   into a small per-worker ring buffer in the arena's `fuse` region
//!   and keeps the trailing `eff_k − 1` halo rows of its input, so the
//!   next tile resumes where the last one stopped — no recompute, and
//!   the dense intermediate activations never round-trip through the
//!   arena. Residual skips, non-sliding kernels, and overlapping pools
//!   break a segment. Fused execution reuses the *exact* per-row-tile
//!   conv body ([`crate::conv`]'s `conv1d_sliding_row_tile_into`) and
//!   the *exact* non-overlapping pool fold of the unfused path, so it
//!   is bit-identical to running the steps separately — for every tile
//!   size, span partitioning, and thread count.
//! * **Arena layout** — one flat `Vec<f32>` holds every intermediate:
//!   `[ act A | act B | residual tmp | im2col col | fuse rings | pool
//!   dense ]`, with region sizes (`act_len`, `tmp_len`, `col_len`,
//!   `fuse_len`, `pool_len`) precomputed at compile time. Step *i*
//!   reads one activation region and writes the other (alternating;
//!   step 0 reads the request input, the last step writes the caller's
//!   output buffer), so execution does no resizing, no ping/pong `Vec`
//!   swaps, and — for all kernels except the faithful-math
//!   `SlidingPair` — no tensor-sized allocation after warm-up (the
//!   only per-request heap traffic is the O(tasks) boxed-job and
//!   sweep-state bookkeeping every parallel dispatch in this crate
//!   already pays — never proportional to activation size). The `pool`
//!   region hands strided *overlapping* pools their dense scratch rows,
//!   so that last allocating layer kind now recycles arena memory too.
//! * **Fused epilogues** — bias is already part of the kernels'
//!   accumulator seed; the ReLU tail and the residual skip-add ride the
//!   kernels' destination writes as an [`Epilogue`] instead of separate
//!   memory passes.
//!
//! [`Plan::run_into`] is bit-identical to the eager reference path
//! ([`Model::forward_eager_into`]) for every fixed backend, thread
//! count, and SIMD tier — enforced by `tests/plan_parity.rs` (which
//! also pins autotuned and fused plans to the eager path with matching
//! per-layer kernels). The serving engines compile and cache plans
//! keyed by batch size ([`crate::coordinator::NativeEngine`]
//! additionally precompiles a configured set of batch buckets at
//! startup, so no request ever pays compile-or-probe latency); the
//! eager [`Model::forward_into`] is itself a compile-then-run wrapper.
//!
//! [`FusedChain`]: PlanKernel::FusedChain

use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::conv::{self, BackendChoice, Conv1dParams, ConvBackend};
use crate::exec::{Executor, PAR_MIN_FANOUT};
use crate::ops::Epilogue;
use crate::pool::{
    pool1d_overlap_strided_with_into, pool1d_row_nonoverlap_tile, pool1d_with_into, Pool1dParams,
    PoolKind, POOL_SCRATCH_TASKS,
};
use crate::simd::SimdTier;
use crate::sliding::Boundary;

use super::layers::{dense_forward, Layer};
use super::Model;

/// Which kernel executes a planned layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanKernel {
    /// Broadcast-FMA sliding-window conv (the paper's contribution).
    Sliding,
    /// im2col + blocked GEMM, column matrix in the plan arena.
    Im2col,
    /// Fused register-blocked small-filter kernel (k ∈ {3, 5}).
    SmallK,
    /// Nested-loop reference conv.
    Direct,
    /// Literal Eq. 7–9 pair-operator prefix sum (allocates; kept for
    /// fidelity, never chosen by the cost model).
    SlidingPair,
    /// int8 sliding conv: dynamic activation quantization into the
    /// plan's i8 scratch, pre-quantized weights, i32 accumulation
    /// (bit-identical across SIMD tiers). Only reachable for layers
    /// that opted in via `quantize = "int8"`.
    QuantizedSliding,
    /// Blocked-GEMM gemv (dense layers).
    Gemm,
    /// Sliding-sum pooling.
    Pool,
    /// Fused chain segment: a maximal run of sliding convs and
    /// non-overlapping pools swept tile-by-tile through per-worker ring
    /// buffers (one arena pass for the whole segment).
    FusedChain,
}

impl PlanKernel {
    pub fn name(&self) -> &'static str {
        match self {
            PlanKernel::Sliding => "sliding",
            PlanKernel::Im2col => "im2col",
            PlanKernel::SmallK => "small_k",
            PlanKernel::Direct => "direct",
            PlanKernel::SlidingPair => "sliding_pair",
            PlanKernel::QuantizedSliding => "int8",
            PlanKernel::Gemm => "gemm",
            PlanKernel::Pool => "pool",
            PlanKernel::FusedChain => "fused_chain",
        }
    }
}

/// Parse a persisted conv-kernel decision name (only the candidates the
/// autotuner probes are valid).
fn parse_conv_kernel(name: &str) -> Option<PlanKernel> {
    match name {
        "sliding" => Some(PlanKernel::Sliding),
        "im2col" => Some(PlanKernel::Im2col),
        "small_k" => Some(PlanKernel::SmallK),
        "direct" => Some(PlanKernel::Direct),
        "int8" => Some(PlanKernel::QuantizedSliding),
        _ => None,
    }
}

/// Planner inputs beyond the model itself.
#[derive(Clone, Copy, Debug)]
pub struct PlannerConfig {
    /// Deployment-level backend selection (`--backend` /
    /// `serve.backend`); per-layer TOML overrides beat it either way.
    pub backend: BackendChoice,
    /// Measured-cost kernel selection: when the decision falls to the
    /// cost model (`Auto` backend, no per-layer override), micro-probe
    /// every candidate kernel against the layer's real shape and
    /// weights and pick the fastest instead of trusting the shape
    /// heuristic. Probe results live in the global [`TuneCache`], so
    /// repeated compiles of the same shape are free.
    pub autotune: bool,
    /// Plan-level chain fusion: sweep maximal runs of sliding convs and
    /// non-overlapping pools through cache-resident ring-buffer tiles
    /// (bit-identical to the unfused plan; on by default). Under
    /// [`PlannerConfig::autotune`] each candidate segment is
    /// micro-probed fused-vs-unfused and only kept fused when measured
    /// faster.
    pub fuse: bool,
    /// Force the fused-chain tile size (final-stage output columns per
    /// sweep step). `None` (the default) sizes the tile so one worker's
    /// ring buffers stay within [`CHAIN_CACHE_ELEMS`]; tests force tiny
    /// tiles to stress the halo handoff.
    pub chain_tile: Option<usize>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            backend: BackendChoice::default(),
            autotune: false,
            fuse: true,
            chain_tile: None,
        }
    }
}

/// One compiled layer step: resolved shapes + chosen kernel. The arena
/// region a step reads/writes follows its position (alternating A/B;
/// first reads the input, last writes the output), so the step itself
/// only carries lengths. A fused step covers two adjacent layers.
#[derive(Clone, Debug)]
struct Step {
    /// Index into the model's layer stack of the step's *first* layer
    /// (weight lookup + validation).
    layer: usize,
    kernel: PlanKernel,
    op: StepOp,
    /// Input elements (`batch · c · n`).
    in_len: usize,
    /// Output elements (`batch · c2 · n2`).
    out_len: usize,
}

#[derive(Clone, Debug)]
enum StepOp {
    Conv { p: Conv1dParams, relu: bool },
    Residual { p: Conv1dParams },
    Pool { kind: PoolKind, p: Pool1dParams },
    Dense { feat: usize, out: usize, relu: bool },
    /// Fused chain segment: every stage streams through per-worker ring
    /// buffers in the arena's fuse region.
    Chain(ChainPlan),
}

// ───────────────────────── fused chain segments ───────────────────────

/// Upper bound on concurrent ring-buffer sets for a fused chain step —
/// bounds the arena's fuse region to `CHAIN_MAX_TASKS · task_elems`
/// elements no matter how many workers the runtime executor has.
const CHAIN_MAX_TASKS: usize = 16;

/// Target ring-buffer footprint per worker, in f32 elements (≈ 192 KiB
/// — comfortably cache-resident on anything with ≥ 256 KiB of L2). The
/// tile size is halved until a sweep fits, so deep segments trade tile
/// width for depth instead of spilling.
const CHAIN_CACHE_ELEMS: usize = 48 * 1024;

/// Tile-size floor: below this the per-tile bookkeeping dominates the
/// kernel work, so the auto-sizer stops halving.
const CHAIN_MIN_TILE: usize = 32;

/// Minimum final-output columns per span when a row is split across
/// workers — each span restarts its halos from scratch, so spans much
/// smaller than this pay more boundary recompute than they win back in
/// parallelism.
const CHAIN_MIN_SPAN: usize = 64;

/// One stage of a fused chain: the resolved op plus the halo geometry
/// (`stride`/`extent`/`pad`) both the compile-time capacity computation
/// and the run-time sweep derive ranges from — sharing the arithmetic
/// is what makes the precomputed ring-buffer capacities exact.
#[derive(Clone, Debug)]
pub(crate) struct ChainStage {
    /// Index into the model's layer stack (weight lookup + validation).
    pub(crate) layer: usize,
    pub(crate) op: ChainOp,
    /// Input / output channels (equal for pools).
    pub(crate) c_in: usize,
    pub(crate) c_out: usize,
    /// Conceptual input / output row lengths.
    pub(crate) n_in: usize,
    pub(crate) n_out: usize,
    /// Output stride.
    pub(crate) stride: usize,
    /// Window extent in input elements (`eff_k` for convs, `w` for
    /// pools).
    pub(crate) extent: usize,
    /// Left zero-padding (convs only; plan pools are valid-mode).
    pub(crate) pad: usize,
    /// Ring-buffer row capacity for this stage's *output* (0 for the
    /// last stage, which writes the step destination directly).
    pub(crate) cap: usize,
    /// Element offset of this stage's ring buffer inside one worker's
    /// chunk of the fuse region.
    pub(crate) buf_off: usize,
}

#[derive(Clone, Debug)]
pub(crate) enum ChainOp {
    Conv { p: Conv1dParams, relu: bool },
    Pool { kind: PoolKind, p: Pool1dParams },
}

impl ChainStage {
    /// First conceptual input index needed to produce output `t` —
    /// also the resume point the previous stage's ring buffer must keep
    /// buffered (everything below it has been fully consumed).
    pub(crate) fn in_lo(&self, t: usize) -> usize {
        (t * self.stride).saturating_sub(self.pad).min(self.n_in)
    }

    /// One past the last conceptual input index needed to produce
    /// outputs `[.., t1)`.
    pub(crate) fn in_hi(&self, t1: usize) -> usize {
        if t1 == 0 {
            return 0;
        }
        ((t1 - 1) * self.stride + self.extent)
            .saturating_sub(self.pad)
            .min(self.n_in)
    }
}

/// A compiled fused-chain segment: stages plus the tile/ring-buffer
/// layout, fixed at compile time so execution never sizes anything.
#[derive(Clone, Debug)]
struct ChainPlan {
    batch: usize,
    stages: Vec<ChainStage>,
    /// Final-stage output columns per sweep step.
    tile: usize,
    /// Ring-buffer elements per worker (sum over non-final stages of
    /// `c_out · cap`).
    task_elems: usize,
    /// Ring-buffer sets the fuse region holds for this segment.
    max_tasks: usize,
    /// Output elements ALL stages produce per batch element (the
    /// segment's real work) — the parallelism gate compares this, not
    /// the final stage's (possibly heavily down-sampled) volume.
    unit_work: usize,
}

/// Fill each non-final stage's ring-buffer capacity (and buffer offset)
/// for the given tile size; returns the per-worker element footprint.
///
/// The capacity bound is the unclamped affine recursion over the halo
/// geometry: with `G[last] = tile` final outputs per sweep step, stage
/// `i` holds at most `s·G[i+1] + (e − s)` buffered elements (`s`/`e`
/// the *next* stage's stride/extent) — the next tile's target `hi`
/// minus the consumed-and-dropped prefix. Clamping at the row ends only
/// shrinks ranges, so the bound is safe; it is also capped at the full
/// row length, which the content can never exceed.
pub(crate) fn chain_task_elems(stages: &mut [ChainStage], tile: usize) -> usize {
    let m = stages.len();
    let mut g = tile.max(1);
    for i in (0..m - 1).rev() {
        let s = stages[i + 1].stride;
        let e = stages[i + 1].extent;
        let grow = s * g + e.saturating_sub(s);
        stages[i].cap = grow.min(stages[i].n_out).max(1);
        g = grow;
    }
    stages[m - 1].cap = 0;
    let mut off = 0usize;
    for st in stages[..m - 1].iter_mut() {
        st.buf_off = off;
        off += st.c_out * st.cap;
    }
    off
}

/// Input-row capacity a *streaming* sweep of `stages` needs: the same
/// affine halo recursion [`chain_task_elems`] runs over stages `1..m`,
/// continued one more hop through stage 0's geometry — with `tile`
/// final outputs as the per-advance target, at most this many input
/// rows are ever buffered between the drop-consumed point and the
/// append of the next packet. Clamped at the full row length, which
/// the content can never exceed.
pub(crate) fn chain_input_cap(stages: &[ChainStage], tile: usize) -> usize {
    let mut g = tile.max(1);
    for st in stages.iter().rev() {
        g = st.stride * g + st.extent.saturating_sub(st.stride);
    }
    g.min(stages[0].n_in).max(1)
}

/// Whether a classified step can join a fused chain: a conv that runs
/// the sliding kernel, or a strided non-overlapping valid-mode pool.
/// Residual blocks (the skip needs the full input), dense layers,
/// non-sliding kernels, and overlapping pools break the segment.
fn chain_eligible(step: &Step) -> bool {
    match &step.op {
        StepOp::Conv { .. } => step.kernel == PlanKernel::Sliding,
        StepOp::Pool { p, .. } => {
            p.stride > 1 && p.stride >= p.w && p.boundary == Boundary::Valid
        }
        _ => false,
    }
}

/// Build the chain layout for a run of eligible raw steps.
fn build_chain(raw: &[Step], batch: usize, cfg: &PlannerConfig) -> Result<ChainPlan> {
    let mut stages: Vec<ChainStage> = Vec::with_capacity(raw.len());
    for s in raw {
        let st = match &s.op {
            StepOp::Conv { p, relu } => ChainStage {
                layer: s.layer,
                c_in: p.c_in,
                c_out: p.c_out,
                n_in: p.n,
                n_out: p.n_out(),
                stride: p.stride,
                extent: p.effective_k(),
                pad: p.pad,
                cap: 0,
                buf_off: 0,
                op: ChainOp::Conv { p: *p, relu: *relu },
            },
            StepOp::Pool { kind, p } => ChainStage {
                layer: s.layer,
                c_in: p.channels,
                c_out: p.channels,
                n_in: p.n,
                n_out: p.n_out(),
                stride: p.stride,
                extent: p.w,
                pad: 0,
                cap: 0,
                buf_off: 0,
                op: ChainOp::Pool { kind: *kind, p: *p },
            },
            _ => bail!("non-chainable step handed to the chain builder"),
        };
        stages.push(st);
    }
    let n_final = stages.last().expect("chains have >= 2 stages").n_out;
    let tile = match cfg.chain_tile {
        Some(t) => t.clamp(1, n_final.max(1)),
        None => {
            let mut t = n_final.max(1);
            while t > CHAIN_MIN_TILE && chain_task_elems(&mut stages, t) > CHAIN_CACHE_ELEMS {
                t /= 2;
            }
            t
        }
    };
    let task_elems = chain_task_elems(&mut stages, tile);
    let max_spans = n_final.div_ceil(CHAIN_MIN_SPAN).clamp(1, CHAIN_MAX_TASKS);
    let max_tasks = (batch * max_spans).min(CHAIN_MAX_TASKS).max(1);
    let unit_work: usize = stages.iter().map(|st| st.c_out * st.n_out).sum();
    Ok(ChainPlan {
        batch,
        stages,
        tile,
        task_elems,
        max_tasks,
        unit_work,
    })
}

/// The scratch a plan executes in: one flat arena
/// `[act A | act B | tmp | col | fuse | pool]`, grown once to the
/// plan's precomputed size and recycled dirty across requests, plus
/// the typed side regions quantized steps need (i8 activation quant
/// buffer and i32 accumulator rows — f32 arena space cannot be
/// reinterpreted without aliasing the audit story).
#[derive(Clone, Debug, Default)]
pub struct PlanScratch {
    arena: Vec<f32>,
    /// Quantized activations of the current int8 step (largest
    /// quantized input across the plan's steps).
    qbuf: Vec<i8>,
    /// i32 accumulator + window-sum rows for int8 steps.
    qacc: Vec<i32>,
}

impl PlanScratch {
    /// Pre-grow the arena to `elems` (engine startup precompilation):
    /// the first request then performs zero allocations.
    pub fn reserve(&mut self, elems: usize) {
        if self.arena.len() < elems {
            self.arena.resize(elems, 0.0);
        }
    }

    /// Current arena size in elements — the allocation-audit surface
    /// (steady-state serving must never grow it).
    pub fn capacity(&self) -> usize {
        self.arena.len()
    }
}

/// Keyed compile-once plan cache (tiny linear scan — one entry per
/// batch bucket / backend pair) with hit/compile counters so serving
/// tests can assert that steady-state inference never compiles. Shared
/// by [`crate::coordinator::NativeEngine`] (keyed by batch size) and
/// [`super::ForwardScratch`](crate::nn::ForwardScratch) (keyed by
/// batch + backend).
#[derive(Clone, Debug)]
pub struct PlanCache<K> {
    entries: Vec<(K, Plan)>,
    hits: u64,
    compiles: u64,
}

impl<K> Default for PlanCache<K> {
    fn default() -> Self {
        Self {
            entries: Vec::new(),
            hits: 0,
            compiles: 0,
        }
    }
}

impl<K: PartialEq + Copy> PlanCache<K> {
    /// The cached plan for `key`, compiling (and caching) on first use.
    pub fn get_or_compile(
        &mut self,
        key: K,
        compile: impl FnOnce() -> Result<Plan>,
    ) -> Result<&Plan> {
        let idx = match self.entries.iter().position(|(k, _)| *k == key) {
            Some(i) => {
                self.hits += 1;
                i
            }
            None => {
                self.entries.push((key, compile()?));
                self.compiles += 1;
                self.entries.len() - 1
            }
        };
        Ok(&self.entries[idx].1)
    }

    /// Number of compiled plans cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups served from the cache (no compile).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Plan compilations performed (cache misses).
    pub fn compiles(&self) -> u64 {
        self.compiles
    }
}

// ───────────────────────── measured cost model ────────────────────────

/// One probed candidate: the kernel and its best measured wall time.
#[derive(Clone, Copy, Debug)]
pub struct ProbeResult {
    pub kernel: PlanKernel,
    /// Best-of-`PROBE_ITERS` wall time in microseconds.
    pub micros: f64,
}

/// Per-layer autotune record kept on the compiled [`Plan`] so the
/// heuristic-vs-measured decision stays auditable (the `e2e_serving`
/// bench prints these next to throughput).
#[derive(Clone, Debug)]
pub struct LayerTune {
    /// Model layer index the probe ran for.
    pub layer: usize,
    pub chosen: PlanKernel,
    /// `true` when the choice came from the [`TuneCache`] (probes then
    /// stay empty — the work happened in an earlier compile).
    pub cached: bool,
    pub probes: Vec<ProbeResult>,
}

/// Per-segment autotune record: under [`PlannerConfig::autotune`] each
/// candidate fused chain is micro-probed against running its stages
/// unfused, so the fuse/no-fuse decision is *measured on the segment*,
/// not inferred from lone-layer timings. Kept on the compiled [`Plan`]
/// for auditability.
#[derive(Clone, Debug)]
pub struct SegmentTune {
    /// First and last model layer index of the candidate segment.
    pub layers: (usize, usize),
    /// Whether the segment compiled fused.
    pub fused: bool,
    /// `true` when the decision came from the [`TuneCache`] (micros
    /// then stay 0 — the measurement happened in an earlier compile or
    /// process).
    pub cached: bool,
    /// Best-of-probes wall time for the fused sweep, microseconds.
    pub fused_micros: f64,
    /// Best-of-probes wall time for the per-stage unfused run.
    pub unfused_micros: f64,
}

/// Timed probe runs per candidate (after one untimed warm-up run); the
/// minimum is taken — short kernels are noisy and min is the robust
/// estimator for "how fast can this kernel go here".
const PROBE_ITERS: usize = 3;

/// Cache key for a probed decision. The shape captures everything the
/// kernels' cost depends on (batch, channels, length, filter, stride,
/// dilation, padding); the SIMD tier and executor width capture the
/// machine configuration — forcing `SWSNN_SIMD=generic` or changing
/// `--threads` re-probes rather than reusing a measurement taken under
/// different kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct TuneKey {
    shape: Conv1dParams,
    tier: SimdTier,
    threads: usize,
    /// Whether the int8 kernel was among the candidates (the layer
    /// opted in via `quantize = "int8"`). Part of the key so a shape
    /// probed f32-only never answers for an opted-in layer.
    quant: bool,
}

/// Key for a fused-vs-unfused segment decision: the segment signature
/// (stage shapes + batch, see [`segment_sig`]) plus the machine
/// configuration.
type SegKey = (String, SimdTier, usize);

#[derive(Default)]
struct TuneInner {
    entries: Vec<(TuneKey, PlanKernel)>,
    segments: Vec<(SegKey, bool)>,
    hits: u64,
    misses: u64,
    /// Write-through persistence target (None = in-memory only).
    persist: Option<PathBuf>,
}

/// Process-wide cache of measured kernel choices, keyed by
/// `(layer shape, SIMD tier, executor threads)`, plus fused-vs-unfused
/// segment decisions keyed by `(segment signature, SIMD tier,
/// threads)`. Shared across engines, batch buckets, and coordinator
/// workers so each distinct shape is probed once per process no matter
/// how many plans compile.
///
/// With persistence enabled ([`TuneCache::enable_persistence`] — the
/// serve CLI turns it on at startup, honoring `SWSNN_TUNE_CACHE`, with
/// `bench_results/tunecache.json` as the default path) decisions are
/// also written through to disk and reloaded on the next start, so
/// replicated restarts skip re-probing entirely. The file is gated on
/// the CPU model string; every entry additionally carries its SIMD
/// tier and thread count, so a changed machine configuration re-probes
/// instead of trusting stale measurements.
#[derive(Default)]
pub struct TuneCache {
    inner: Mutex<TuneInner>,
}

impl TuneCache {
    /// The process-wide cache.
    pub fn global() -> &'static TuneCache {
        static GLOBAL: OnceLock<TuneCache> = OnceLock::new();
        GLOBAL.get_or_init(TuneCache::default)
    }

    fn lookup(&self, key: &TuneKey) -> Option<PlanKernel> {
        let mut g = self.inner.lock().unwrap();
        let found = g.entries.iter().find(|(k, _)| k == key).map(|(_, v)| *v);
        if found.is_some() {
            g.hits += 1;
        } else {
            g.misses += 1;
        }
        found
    }

    /// Insert-or-get: the first inserted decision is canonical. Two
    /// replicated workers can probe the same shape concurrently (both
    /// miss `lookup`, then race here); the loser adopts the winner's
    /// kernel instead of keeping its own measurement, so every worker's
    /// plans execute the same kernels — identical requests stay
    /// bit-identical across workers.
    fn insert(&self, key: TuneKey, kernel: PlanKernel) -> PlanKernel {
        let mut g = self.inner.lock().unwrap();
        if let Some((_, existing)) = g.entries.iter().find(|(k, _)| *k == key) {
            return *existing;
        }
        g.entries.push((key, kernel));
        let snapshot = persist_snapshot(&g);
        drop(g);
        write_snapshot(snapshot);
        kernel
    }

    fn lookup_segment(&self, key: &SegKey) -> Option<bool> {
        let mut g = self.inner.lock().unwrap();
        let found = g.segments.iter().find(|(k, _)| k == key).map(|(_, v)| *v);
        if found.is_some() {
            g.hits += 1;
        } else {
            g.misses += 1;
        }
        found
    }

    /// Insert-or-get for segment decisions (same first-writer-wins
    /// contract as [`TuneCache::insert`]).
    fn insert_segment(&self, key: SegKey, fused: bool) -> bool {
        let mut g = self.inner.lock().unwrap();
        if let Some((_, existing)) = g.segments.iter().find(|(k, _)| *k == key) {
            return *existing;
        }
        g.segments.push((key, fused));
        let snapshot = persist_snapshot(&g);
        drop(g);
        write_snapshot(snapshot);
        fused
    }

    /// Distinct probed kernel decisions cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Distinct probed segment decisions cached.
    pub fn segments_len(&self) -> usize {
        self.inner.lock().unwrap().segments.len()
    }

    /// Lookups answered from the cache.
    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    /// Lookups that had to probe.
    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }

    /// Turn on disk persistence: load whatever a previous process
    /// recorded for this CPU model, then write every new decision
    /// through. `path = None` resolves `SWSNN_TUNE_CACHE` (a path; the
    /// values `off`, `0`, or empty disable persistence) and falls back
    /// to `bench_results/tunecache.json`. Returns the number of entries
    /// loaded. Tests and tools can instead call
    /// [`TuneCache::save_to`] / [`TuneCache::load_from`] on explicit
    /// paths without touching process-global state.
    pub fn enable_persistence(&self, path: Option<PathBuf>) -> usize {
        let resolved = match path {
            Some(p) => Some(p),
            None => match std::env::var("SWSNN_TUNE_CACHE") {
                Ok(v) if v.is_empty() || v == "off" || v == "0" => None,
                Ok(v) => Some(PathBuf::from(v)),
                Err(_) => Some(PathBuf::from("bench_results/tunecache.json")),
            },
        };
        let Some(p) = resolved else { return 0 };
        // A missing file is the normal first run; anything else (I/O
        // error, non-UTF-8 bytes, …) is logged and treated as an empty
        // cache — a corrupt snapshot must never take the process down,
        // it just costs re-probing.
        let loaded = match self.load_from(&p) {
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => 0,
            Err(e) => {
                eprintln!(
                    "swsnn: tune cache {} unreadable ({e}); starting empty",
                    p.display()
                );
                0
            }
        };
        self.inner.lock().unwrap().persist = Some(p);
        loaded
    }

    /// Serialize every cached decision to `path` (hand-rolled JSON —
    /// serde is unavailable offline), tagged with this machine's CPU
    /// model string.
    pub fn save_to(&self, path: &Path) -> std::io::Result<()> {
        let g = self.inner.lock().unwrap();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, render_tune_json(&g.entries, &g.segments))
    }

    /// Merge decisions persisted by a previous process. Entries are
    /// ignored wholesale when the file's CPU model differs from this
    /// machine's, and individually when already present (in-memory
    /// probes win) or malformed. Returns the number of entries merged.
    pub fn load_from(&self, path: &Path) -> std::io::Result<usize> {
        let text = std::fs::read_to_string(path)?;
        let my_cpu = json_escape(&cpu_model());
        let Some(pos) = text.find("\"cpu\":\"") else {
            return Ok(0);
        };
        let after = &text[pos + 7..];
        let Some(e) = after.find('"') else {
            return Ok(0);
        };
        if after[..e] != my_cpu {
            return Ok(0);
        }
        let mut loaded = 0usize;
        let mut g = self.inner.lock().unwrap();
        for obj in nested_objects(&text) {
            if let Some(kname) = obj_field(obj, "kernel") {
                let Some(kernel) = parse_conv_kernel(kname) else {
                    continue;
                };
                let Some(tier) = obj_field(obj, "tier").and_then(SimdTier::parse) else {
                    continue;
                };
                let Some(threads) = obj_usize(obj, "threads") else {
                    continue;
                };
                let (Some(batch), Some(c_in), Some(c_out), Some(n)) = (
                    obj_usize(obj, "batch"),
                    obj_usize(obj, "c_in"),
                    obj_usize(obj, "c_out"),
                    obj_usize(obj, "n"),
                ) else {
                    continue;
                };
                let (Some(k), Some(stride), Some(dilation), Some(pad)) = (
                    obj_usize(obj, "k"),
                    obj_usize(obj, "stride"),
                    obj_usize(obj, "dilation"),
                    obj_usize(obj, "pad"),
                ) else {
                    continue;
                };
                if k < 1 || stride < 1 || dilation < 1 || threads < 1 {
                    continue;
                }
                // Files written before the int8 kernel existed carry no
                // "quant" field — those probes ran f32-only.
                let quant = obj_field(obj, "quant") == Some("true");
                let key = TuneKey {
                    shape: Conv1dParams {
                        batch,
                        c_in,
                        c_out,
                        n,
                        k,
                        stride,
                        dilation,
                        pad,
                    },
                    tier,
                    threads,
                    quant,
                };
                if !g.entries.iter().any(|(existing, _)| *existing == key) {
                    g.entries.push((key, kernel));
                    loaded += 1;
                }
            } else if let Some(fused) = obj_field(obj, "fused") {
                let fused = fused == "true";
                let (Some(sig), Some(tier), Some(threads)) = (
                    obj_field(obj, "sig"),
                    obj_field(obj, "tier").and_then(SimdTier::parse),
                    obj_usize(obj, "threads"),
                ) else {
                    continue;
                };
                if threads < 1 {
                    continue;
                }
                let key: SegKey = (sig.to_string(), tier, threads);
                if !g.segments.iter().any(|(existing, _)| *existing == key) {
                    g.segments.push((key, fused));
                    loaded += 1;
                }
            }
        }
        Ok(loaded)
    }
}

/// Render the write-through snapshot while the lock is held
/// (CPU-only serialization — probing is compile-time, and the files are
/// tiny). Returns `None` unless persistence is enabled.
fn persist_snapshot(g: &TuneInner) -> Option<(PathBuf, String)> {
    let path = g.persist.as_ref()?;
    Some((path.clone(), render_tune_json(&g.entries, &g.segments)))
}

/// Perform the blocking disk I/O *after* the cache lock is dropped, so
/// concurrently-warming workers never queue behind a file write. Each
/// write stages through its own uniquely-named temp file (pid +
/// process-wide counter — two racing writers must never interleave on
/// one inode) and lands with an atomic rename, so the target is always
/// well-formed. Racing inserts may land their snapshots out of order;
/// any decision the losing write momentarily dropped is re-persisted
/// by the next insert — the on-disk cache is advisory, the in-memory
/// one is canonical. Failures are swallowed: the cache stays correct
/// in memory.
fn write_snapshot(snapshot: Option<(PathBuf, String)>) {
    use std::sync::atomic::{AtomicU64, Ordering};
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let Some((path, text)) = snapshot else { return };
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(dir);
        }
    }
    let tmp = path.with_extension(format!(
        "json.tmp.{}.{}",
        std::process::id(),
        WRITE_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    if std::fs::write(&tmp, text).is_ok() && std::fs::rename(&tmp, &path).is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
}

fn render_tune_json(entries: &[(TuneKey, PlanKernel)], segments: &[(SegKey, bool)]) -> String {
    let kernels: Vec<String> = entries
        .iter()
        .map(|(k, v)| {
            format!(
                "{{\"batch\":{},\"c_in\":{},\"c_out\":{},\"n\":{},\"k\":{},\"stride\":{},\"dilation\":{},\"pad\":{},\"tier\":\"{}\",\"threads\":{},\"quant\":{},\"kernel\":\"{}\"}}",
                k.shape.batch,
                k.shape.c_in,
                k.shape.c_out,
                k.shape.n,
                k.shape.k,
                k.shape.stride,
                k.shape.dilation,
                k.shape.pad,
                k.tier.name(),
                k.threads,
                k.quant,
                v.name()
            )
        })
        .collect();
    let segs: Vec<String> = segments
        .iter()
        .map(|((sig, tier, threads), fused)| {
            format!(
                "{{\"sig\":\"{}\",\"tier\":\"{}\",\"threads\":{},\"fused\":{}}}",
                json_escape(sig),
                tier.name(),
                threads,
                fused
            )
        })
        .collect();
    format!(
        "{{\n\"cpu\":\"{}\",\n\"kernels\":[\n{}\n],\n\"segments\":[\n{}\n]\n}}\n",
        json_escape(&cpu_model()),
        kernels.join(",\n"),
        segs.join(",\n")
    )
}

/// The CPU model string the persisted cache is keyed by: measurements
/// do not transfer across microarchitectures, so a file recorded on a
/// different machine is ignored wholesale.
fn cpu_model() -> String {
    #[cfg(target_os = "linux")]
    {
        if let Ok(info) = std::fs::read_to_string("/proc/cpuinfo") {
            for line in info.lines() {
                if let Some((key, val)) = line.split_once(':') {
                    if key.trim() == "model name" {
                        return val.trim().to_string();
                    }
                }
            }
        }
    }
    std::env::consts::ARCH.to_string()
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The entry objects of a persisted tune file (the nested `{...}`
/// literals after the outer brace). Entry objects never nest and the
/// strings we write never contain braces, so a flat scan suffices —
/// this parser only ever reads files this module wrote, and anything
/// malformed is simply skipped.
fn nested_objects(text: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let Some(first) = text.find('{') else {
        return out;
    };
    let mut rest = &text[first + 1..];
    while let Some(s) = rest.find('{') {
        let after = &rest[s + 1..];
        let Some(e) = after.find('}') else { break };
        out.push(&after[..e]);
        rest = &after[e + 1..];
    }
    out
}

/// Extract the raw value of `"key":` from an entry object: quoted
/// strings are returned unquoted, other values run to the next comma.
fn obj_field<'a>(obj: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let pos = obj.find(&pat)?;
    let rest = obj[pos + pat.len()..].trim_start();
    if let Some(stripped) = rest.strip_prefix('"') {
        let end = stripped.find('"')?;
        Some(&stripped[..end])
    } else {
        let end = rest.find(',').unwrap_or(rest.len());
        Some(rest[..end].trim())
    }
}

fn obj_usize(obj: &str, key: &str) -> Option<usize> {
    obj_field(obj, key)?.parse().ok()
}

/// Pre-quantized weights for a layer compiled to the int8 kernel:
/// built once at [`Plan::compile`] from the actual weight range, so
/// requests never touch f32 weights on a quantized step.
#[derive(Clone, Debug)]
pub struct QuantLayer {
    qw: Vec<i8>,
    w_params: conv::QuantParams,
}

impl QuantLayer {
    fn from_weights(w: &[f32]) -> Self {
        let w_params = conv::QuantParams::from_slice(w);
        Self {
            qw: w_params.quantize_slice(w),
            w_params,
        }
    }
}

/// Reused probe buffers (compile-time only — probing allocates once per
/// compile, never on the request path).
#[derive(Default)]
struct ProbeScratch {
    x: Vec<f32>,
    y: Vec<f32>,
    col: Vec<f32>,
    /// int8 probe scratch (activation quant buffer + i32 accumulators).
    qx: Vec<i8>,
    qacc: Vec<i32>,
}

impl ProbeScratch {
    /// Size the buffers for one layer shape and fill the input with a
    /// small deterministic non-zero pattern (denormals/zeros can skew
    /// kernel timing).
    fn fill(&mut self, p: &Conv1dParams) {
        self.x.clear();
        self.x
            .extend((0..p.x_len()).map(|i| ((i % 29) as f32) * 0.0625 - 0.875));
        self.y.resize(p.y_len(), 0.0);
        self.col.resize(p.c_in * p.k * p.n_out(), 0.0);
    }
}

/// Run every candidate kernel against the layer's real shape and
/// weights; returns the measured times in candidate order. `quant`
/// adds the int8 kernel to the field (opted-in layers only); its probe
/// times the *whole* per-request pipeline — range scan, activation
/// quantization, and the quantized conv — so the measurement reflects
/// what execution actually pays.
fn probe_candidates(
    ex: &Executor,
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    quant: bool,
    scratch: &mut ProbeScratch,
) -> Result<Vec<ProbeResult>> {
    let mut cands = vec![PlanKernel::Sliding];
    if conv::small_k_qualifies(p) {
        cands.push(PlanKernel::SmallK);
    }
    cands.push(PlanKernel::Im2col);
    cands.push(PlanKernel::Direct);
    // Last: ties go to the earlier (f32) candidate, so int8 must
    // measure strictly faster to win.
    if quant {
        cands.push(PlanKernel::QuantizedSliding);
    }
    scratch.fill(p);
    let ql = if quant {
        scratch.qx.resize(p.x_len(), 0);
        scratch.qacc.resize(conv::quantized_scratch_len(p), 0);
        Some(QuantLayer::from_weights(w))
    } else {
        None
    };
    let mut out = Vec::with_capacity(cands.len());
    for kernel in cands {
        let mut run_once = |scratch: &mut ProbeScratch| -> Result<()> {
            if kernel == PlanKernel::QuantizedSliding {
                let ql = ql.as_ref().expect("quant candidate implies weights");
                run_conv_quantized(
                    &scratch.x,
                    ql,
                    bias,
                    p,
                    Epilogue::None,
                    &mut scratch.qx,
                    &mut scratch.qacc,
                    &mut scratch.y,
                );
                Ok(())
            } else {
                run_conv(
                    ex,
                    kernel,
                    &scratch.x,
                    w,
                    bias,
                    p,
                    Epilogue::None,
                    &mut scratch.col,
                    &mut scratch.y,
                )
            }
        };
        // Untimed warm-up: fault in buffers, settle the dispatch.
        run_once(scratch)?;
        let mut best = f64::INFINITY;
        for _ in 0..PROBE_ITERS {
            let t0 = Instant::now();
            run_once(scratch)?;
            best = best.min(t0.elapsed().as_secs_f64() * 1e6);
        }
        out.push(ProbeResult { kernel, micros: best });
    }
    Ok(out)
}

/// Measured kernel choice for one layer: consult the [`TuneCache`],
/// probe on a miss, record the decision on the plan's tune log either
/// way.
#[allow(clippy::too_many_arguments)]
fn measured_kernel(
    ex: &Executor,
    layer: usize,
    p: &Conv1dParams,
    w: &[f32],
    bias: Option<&[f32]>,
    quant: bool,
    probe: &mut ProbeScratch,
    tunes: &mut Vec<LayerTune>,
) -> Result<PlanKernel> {
    let key = TuneKey {
        shape: *p,
        tier: crate::simd::tier(),
        threads: ex.threads(),
        quant,
    };
    if let Some(kernel) = TuneCache::global().lookup(&key) {
        tunes.push(LayerTune {
            layer,
            chosen: kernel,
            cached: true,
            probes: Vec::new(),
        });
        return Ok(kernel);
    }
    let probes = probe_candidates(ex, w, bias, p, quant, probe)?;
    let mut chosen = probes[0];
    for pr in &probes[1..] {
        // Strict `<`: ties keep the earlier candidate (sliding first —
        // the paper's kernel wins the coin flips).
        if pr.micros < chosen.micros {
            chosen = *pr;
        }
    }
    // The cache's first writer wins: adopt whatever it returns so
    // concurrently probing workers all run the same kernel.
    let canonical = TuneCache::global().insert(key, chosen.kernel);
    tunes.push(LayerTune {
        layer,
        chosen: canonical,
        cached: false,
        probes,
    });
    Ok(canonical)
}

/// A compiled execution plan for one `(model, batch)` pair. Cheap to
/// clone — no f32 parameter copies (weights stay in the [`Model`] the
/// plan is run against); layers compiled to the int8 kernel carry
/// their pre-quantized i8 weights, which clone at a quarter of f32
/// size and only exist for opted-in layers.
#[derive(Clone, Debug)]
pub struct Plan {
    batch: usize,
    steps: Vec<Step>,
    /// Model layer count the plan was compiled from (≥ `steps.len()`;
    /// fusion folds adjacent layers into one step).
    n_layers: usize,
    /// Elements per activation ping/pong region (max intermediate).
    act_len: usize,
    /// Elements for the residual intermediate region.
    tmp_len: usize,
    /// Elements for the im2col column region (largest im2col layer).
    col_len: usize,
    /// Elements for the fused-chain ring buffers (largest fused
    /// segment's `max_tasks · task_elems`; zero when nothing fused).
    fuse_len: usize,
    /// Elements for the strided overlapping-pool dense scratch rows
    /// (largest such pool step; zero when none).
    pool_len: usize,
    in_len: usize,
    out_c: usize,
    out_n: usize,
    /// Pre-quantized weights per model layer (`None` = f32 execution;
    /// `Some` exactly where the compiled kernel is
    /// [`PlanKernel::QuantizedSliding`]).
    quant: Vec<Option<QuantLayer>>,
    /// Elements for the i8 activation-quant scratch (largest quantized
    /// step input; zero when nothing quantized).
    qbuf_len: usize,
    /// Elements for the i32 accumulator scratch of quantized steps.
    qacc_len: usize,
    /// Autotune audit log (empty unless compiled with
    /// [`PlannerConfig::autotune`]).
    tunes: Vec<LayerTune>,
    /// Segment fuse/no-fuse audit log (empty unless autotuned).
    seg_tunes: Vec<SegmentTune>,
}

/// Shape-based kernel choice for a conv-shaped layer under `Auto`.
///
/// The heuristic mirrors the paper's Fig-1 crossover plus the §5
/// small-filter note:
/// * the fused small-k kernel when it applies (single channel, unit
///   stride/dilation, k ∈ {3, 5} — highest arithmetic intensity per
///   load of all paths);
/// * im2col + GEMM when the channel reduction is fat enough to feed the
///   8×8 microkernel (`c_out ≥ 8`, `c_in·k ≥ 48`) **and** the receptive
///   field is small (`effective_k ≤ 9`) — there the sliding schedule
///   degenerates to a few short passes while the k× expansion stays
///   cheap;
/// * the sliding kernel everywhere else (large filters, thin channel
///   counts, dilated stacks — the shapes the paper shows it winning).
///
/// These boundaries were hand-fit to one machine; the measured mode
/// ([`PlannerConfig::autotune`]) exists because they do not transfer.
/// The heuristic stays as the probe-free default and its boundaries are
/// pinned by unit tests so autotune work cannot silently shift them.
pub fn choose_kernel(p: &Conv1dParams) -> PlanKernel {
    if conv::small_k_qualifies(p) {
        PlanKernel::SmallK
    } else if p.c_out >= 8 && p.c_in * p.k >= 48 && p.effective_k() <= 9 {
        PlanKernel::Im2col
    } else {
        PlanKernel::Sliding
    }
}

fn kernel_for_backend(b: ConvBackend) -> PlanKernel {
    match b {
        ConvBackend::Sliding => PlanKernel::Sliding,
        ConvBackend::Im2colGemm => PlanKernel::Im2col,
        ConvBackend::Direct => PlanKernel::Direct,
        ConvBackend::SlidingPair => PlanKernel::SlidingPair,
    }
}

/// Kernel choice for one conv-shaped layer. Priority: per-layer TOML
/// override > fixed deployment backend > measured probe (autotune) >
/// per-layer `quantize = "int8"` opt-in > shape heuristic. An explicit
/// backend name (per-layer or deployment-fixed) always wins — naming a
/// kernel beats an opt-in hint. Under autotune the opt-in adds int8 to
/// the probe field, so it only runs where it measures faster; without
/// autotune the opt-in is taken at its word.
#[allow(clippy::too_many_arguments)]
fn select_kernel(
    model: &Model,
    cfg: &PlannerConfig,
    layer: usize,
    p: &Conv1dParams,
    w: &[f32],
    bias: Option<&[f32]>,
    ex: &Executor,
    probe: &mut ProbeScratch,
    tunes: &mut Vec<LayerTune>,
) -> Result<PlanKernel> {
    let quant = model.quantize_hint(layer);
    Ok(match model.backend_override(layer) {
        Some(b) => kernel_for_backend(b),
        None => match cfg.backend {
            BackendChoice::Fixed(b) => kernel_for_backend(b),
            BackendChoice::Auto if cfg.autotune => {
                measured_kernel(ex, layer, p, w, bias, quant, probe, tunes)?
            }
            BackendChoice::Auto if quant => PlanKernel::QuantizedSliding,
            BackendChoice::Auto => choose_kernel(p),
        },
    })
}

impl Plan {
    /// Compile the model for one batch size. Runs once per batch bucket;
    /// everything shape- or choice-dependent happens here — including
    /// the autotune probes and the chain-fusion grouping pass.
    pub fn compile(model: &Model, batch: usize, cfg: &PlannerConfig) -> Result<Plan> {
        ensure!(batch >= 1, "plan batch must be >= 1");
        ensure!(
            model.layer_count() > 0,
            "cannot compile a plan for an empty model"
        );
        let nlayers = model.layer_count();
        let layers = model.layers();
        let ex = Executor::global();
        let (mut c, mut n) = (model.c_in, model.seq_len);
        // ── pass 1: classify every layer into a single raw step ──────
        // (shape resolution + kernel selection, exactly one probe/tune
        // record per conv-shaped layer; fusion happens in pass 2 over
        // the classified list, so speculative grouping can never
        // double-probe a layer).
        let mut raw: Vec<Step> = Vec::with_capacity(nlayers);
        let (mut tmp_len, mut col_len) = (0usize, 0usize);
        let (mut qbuf_len, mut qacc_len) = (0usize, 0usize);
        let mut quant: Vec<Option<QuantLayer>> = vec![None; nlayers];
        let mut tunes: Vec<LayerTune> = Vec::new();
        let mut probe = ProbeScratch::default();
        for i in 0..nlayers {
            let layer = &layers[i];
            let in_len = batch * c * n;
            let (kernel, op) = match layer {
                Layer::Conv {
                    c_in,
                    c_out,
                    k,
                    stride,
                    dilation,
                    same_pad,
                    relu,
                    w,
                    b,
                } => {
                    ensure!(c == *c_in, "layer {i}: conv input channels");
                    let mut p = Conv1dParams::new(*c_in, *c_out, n, *k)
                        .with_batch(batch)
                        .with_stride(*stride)
                        .with_dilation(*dilation);
                    if *same_pad {
                        p = p.with_same_pad();
                    }
                    let kernel =
                        select_kernel(model, cfg, i, &p, w, Some(b), ex, &mut probe, &mut tunes)?;
                    if kernel == PlanKernel::Im2col {
                        col_len = col_len.max(p.c_in * p.k * p.n_out());
                    }
                    if kernel == PlanKernel::QuantizedSliding {
                        // Weight-quantization pass: quantize once here
                        // from the actual weight range; requests only
                        // ever quantize activations.
                        quant[i] = Some(QuantLayer::from_weights(w));
                        qbuf_len = qbuf_len.max(p.x_len());
                        qacc_len = qacc_len.max(conv::quantized_scratch_len(&p));
                    }
                    (kernel, StepOp::Conv { p, relu: *relu })
                }
                Layer::Residual {
                    c: cr,
                    k,
                    dilation,
                    w1,
                    b1,
                    ..
                } => {
                    ensure!(c == *cr, "layer {i}: residual channels");
                    let p = Conv1dParams::new(*cr, *cr, n, *k)
                        .with_batch(batch)
                        .with_dilation(*dilation)
                        .with_same_pad();
                    let kernel =
                        select_kernel(model, cfg, i, &p, w1, Some(b1), ex, &mut probe, &mut tunes)?;
                    if kernel == PlanKernel::Im2col {
                        col_len = col_len.max(p.c_in * p.k * p.n_out());
                    }
                    tmp_len = tmp_len.max(in_len);
                    (kernel, StepOp::Residual { p })
                }
                Layer::Pool { kind, w, stride } => {
                    let p = Pool1dParams::new(c, n, *w).with_batch(batch).with_stride(*stride);
                    (PlanKernel::Pool, StepOp::Pool { kind: *kind, p })
                }
                Layer::Dense {
                    in_features,
                    out,
                    relu,
                    ..
                } => {
                    ensure!(c * n == *in_features, "layer {i}: dense input features");
                    (
                        PlanKernel::Gemm,
                        StepOp::Dense {
                            feat: *in_features,
                            out: *out,
                            relu: *relu,
                        },
                    )
                }
            };
            let (c2, n2) = layer.out_shape(c, n);
            ensure!(n2 > 0, "layer {i} produces empty output (c={c}, n={n})");
            let out_len = batch * c2 * n2;
            raw.push(Step {
                layer: i,
                kernel,
                op,
                in_len,
                out_len,
            });
            c = c2;
            n = n2;
        }
        // ── pass 2: chain-fusion grouping ────────────────────────────
        // Greedily take every maximal run of eligible steps (≥ 2 layers
        // with at least one conv — a lone pool gains nothing). Under
        // autotune, each candidate segment is micro-probed fused vs
        // unfused and only kept when the fused sweep measures faster.
        let mut steps: Vec<Step> = Vec::with_capacity(raw.len());
        let mut fuse_len = 0usize;
        let mut seg_tunes: Vec<SegmentTune> = Vec::new();
        let mut i = 0usize;
        while i < raw.len() {
            if cfg.fuse {
                let mut j = i;
                let mut has_conv = false;
                while j < raw.len() && chain_eligible(&raw[j]) {
                    if matches!(raw[j].op, StepOp::Conv { .. }) {
                        has_conv = true;
                    }
                    j += 1;
                }
                if has_conv && j - i >= 2 {
                    let chain = build_chain(&raw[i..j], batch, cfg)?;
                    let keep = if cfg.autotune {
                        probe_segment(ex, model, &chain, &raw[i..j], &mut seg_tunes)?
                    } else {
                        true
                    };
                    if keep {
                        fuse_len = fuse_len.max(chain.max_tasks * chain.task_elems);
                        steps.push(Step {
                            layer: raw[i].layer,
                            kernel: PlanKernel::FusedChain,
                            in_len: raw[i].in_len,
                            out_len: raw[j - 1].out_len,
                            op: StepOp::Chain(chain),
                        });
                        i = j;
                        continue;
                    }
                }
            }
            steps.push(raw[i].clone());
            i += 1;
        }
        // ── region sizing over the final step list ───────────────────
        // Fused intermediates never materialize, so the activation
        // ping/pong regions only need the largest *chain-boundary*
        // activation; the pool region covers the largest overlapping
        // strided pool's dense scratch rows.
        let mut act_len = 0usize;
        let mut pool_len = 0usize;
        let last = steps.len() - 1;
        for (si, s) in steps.iter().enumerate() {
            if si < last {
                act_len = act_len.max(s.out_len);
            }
            if let StepOp::Pool { p, .. } = &s.op {
                if p.stride > 1 && p.stride < p.w && p.boundary == Boundary::Valid {
                    let tasks = (p.batch * p.channels).min(POOL_SCRATCH_TASKS);
                    pool_len = pool_len.max(tasks * p.dense_len());
                }
            }
        }
        let plan = Plan {
            batch,
            steps,
            n_layers: nlayers,
            act_len,
            tmp_len,
            col_len,
            fuse_len,
            pool_len,
            in_len: batch * model.c_in * model.seq_len,
            out_c: c,
            out_n: n,
            quant,
            qbuf_len,
            qacc_len,
            tunes,
            seg_tunes,
        };
        plan.audit_arena_layout();
        Ok(plan)
    }

    /// The batch size this plan was compiled for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Flatten this plan into one fused-chain stage sequence for
    /// streaming sessions ([`crate::nn::session`]): every step must
    /// have a tile-sweepable form — fused chains contribute their
    /// compiled stages verbatim, standalone sliding-family convs and
    /// non-overlapping valid pools become single stages. Residual
    /// blocks (the skip path needs the full input), dense heads,
    /// im2col/direct/int8 kernels, and overlapping pools have no
    /// incremental form and fail the conversion. `model` is
    /// cross-checked the same way [`Plan::run_with_into`] checks it.
    ///
    /// The returned stages carry zeroed ring capacities; the session
    /// layer sizes them for its own tile via [`chain_task_elems`].
    pub(crate) fn stream_stages(&self, model: &Model) -> Result<Vec<ChainStage>> {
        ensure!(
            model.layer_count() == self.n_layers,
            "plan compiled for a different model (layer count {} vs {})",
            self.n_layers,
            model.layer_count()
        );
        ensure!(
            self.batch == 1,
            "streaming sessions are single-stream: compile the plan at batch 1 (got {})",
            self.batch
        );
        let mut stages: Vec<ChainStage> = Vec::new();
        for step in &self.steps {
            match &step.op {
                StepOp::Chain(chain) => {
                    for st in &chain.stages {
                        stages.push(ChainStage {
                            cap: 0,
                            buf_off: 0,
                            ..st.clone()
                        });
                    }
                }
                StepOp::Conv { p, relu } => {
                    ensure!(
                        matches!(step.kernel, PlanKernel::Sliding | PlanKernel::SmallK),
                        "layer {}: {} kernel has no streaming tile form (sliding-family only)",
                        step.layer,
                        step.kernel.name()
                    );
                    stages.push(ChainStage {
                        layer: step.layer,
                        c_in: p.c_in,
                        c_out: p.c_out,
                        n_in: p.n,
                        n_out: p.n_out(),
                        stride: p.stride,
                        extent: p.effective_k(),
                        pad: p.pad,
                        cap: 0,
                        buf_off: 0,
                        op: ChainOp::Conv { p: *p, relu: *relu },
                    });
                }
                StepOp::Pool { kind, p } => {
                    ensure!(
                        p.stride > 1 && p.stride >= p.w && p.boundary == Boundary::Valid,
                        "layer {}: overlapping or dense pool has no streaming tile form",
                        step.layer
                    );
                    stages.push(ChainStage {
                        layer: step.layer,
                        c_in: p.channels,
                        c_out: p.channels,
                        n_in: p.n,
                        n_out: p.n_out(),
                        stride: p.stride,
                        extent: p.w,
                        pad: 0,
                        cap: 0,
                        buf_off: 0,
                        op: ChainOp::Pool { kind: *kind, p: *p },
                    });
                }
                StepOp::Residual { .. } => bail!(
                    "layer {}: residual blocks cannot stream (the skip path needs the full input)",
                    step.layer
                ),
                StepOp::Dense { .. } => {
                    bail!("layer {}: dense heads cannot stream", step.layer)
                }
            }
        }
        ensure!(!stages.is_empty(), "plan has no steps");
        // Stage ↔ layer pairing, same check the chain executor makes.
        for st in &stages {
            let ok = matches!(
                (&st.op, &model.layers()[st.layer]),
                (ChainOp::Conv { .. }, Layer::Conv { .. })
                    | (ChainOp::Pool { .. }, Layer::Pool { .. })
            );
            ensure!(
                ok,
                "stream stage {} does not match the model's layer kind",
                st.layer
            );
        }
        Ok(stages)
    }

    /// Total arena elements: `2·act + tmp + col + fuse + pool`.
    pub fn arena_len(&self) -> usize {
        2 * self.act_len + self.tmp_len + self.col_len + self.fuse_len + self.pool_len
    }

    /// Checked-build arena audit (docs/invariants.md). The arena regions
    /// `[act A | act B | tmp | col | fuse | pool]` are disjoint by
    /// construction (`split_at_mut` carving in `run_with_into`), so what
    /// can actually drift is the *sizing* pass above: a step whose
    /// buffer demand exceeds its region would slice out of bounds at run
    /// time, inside a serving request. Re-derive every step's demand
    /// here, at compile, where a failure is cheap and attributable.
    /// Compiled in for debug and `check-invariants` builds only.
    fn audit_arena_layout(&self) {
        if !(cfg!(debug_assertions) || cfg!(feature = "check-invariants")) {
            return;
        }
        let last = self.steps.len() - 1;
        let mut expect_in = self.in_len;
        for (si, s) in self.steps.iter().enumerate() {
            crate::invariant!(
                s.in_len == expect_in,
                "arena audit: step {si} input length disagrees with the previous step's output"
            );
            expect_in = s.out_len;
            if si < last {
                crate::invariant!(
                    s.out_len <= self.act_len,
                    "arena audit: step {si} output exceeds the activation region"
                );
            }
            match &s.op {
                StepOp::Conv { p, .. } => {
                    if s.kernel == PlanKernel::Im2col {
                        crate::invariant!(
                            p.c_in * p.k * p.n_out() <= self.col_len,
                            "arena audit: step {si} im2col columns exceed the col region"
                        );
                    }
                    if s.kernel == PlanKernel::QuantizedSliding {
                        crate::invariant!(
                            p.x_len() <= self.qbuf_len,
                            "arena audit: step {si} quantized input exceeds the qbuf region"
                        );
                        crate::invariant!(
                            conv::quantized_scratch_len(p) <= self.qacc_len,
                            "arena audit: step {si} quantized accumulators exceed the qacc region"
                        );
                        crate::invariant!(
                            self.quant.get(s.layer).is_some_and(|q| q.is_some()),
                            "arena audit: step {si} quantized step has no pre-quantized weights"
                        );
                    }
                }
                StepOp::Residual { p } => {
                    crate::invariant!(
                        s.in_len <= self.tmp_len,
                        "arena audit: step {si} residual intermediate exceeds the tmp region"
                    );
                    if s.kernel == PlanKernel::Im2col {
                        crate::invariant!(
                            p.c_in * p.k * p.n_out() <= self.col_len,
                            "arena audit: step {si} im2col columns exceed the col region"
                        );
                    }
                }
                StepOp::Pool { p, .. } => {
                    if p.stride > 1 && p.stride < p.w && p.boundary == Boundary::Valid {
                        let tasks = (p.batch * p.channels).min(POOL_SCRATCH_TASKS);
                        crate::invariant!(
                            tasks * p.dense_len() <= self.pool_len,
                            "arena audit: step {si} pool dense scratch exceeds the pool region"
                        );
                    }
                }
                StepOp::Chain(chain) => {
                    crate::invariant!(
                        chain.max_tasks * chain.task_elems <= self.fuse_len,
                        "arena audit: step {si} fused-chain scratch exceeds the fuse region"
                    );
                }
                StepOp::Dense { .. } => {}
            }
        }
        crate::invariant!(
            expect_in == self.batch * self.out_c * self.out_n,
            "arena audit: final step output disagrees with the plan's output shape"
        );
    }

    /// The chosen kernel per *step* (fused segments appear once).
    pub fn kernels(&self) -> Vec<PlanKernel> {
        self.steps.iter().map(|s| s.kernel).collect()
    }

    /// The chosen kernel per *model layer*, expanding fused segments
    /// back to their constituent layers — the audit surface parity
    /// tests map onto eager per-layer backend overrides.
    pub fn layer_kernels(&self) -> Vec<PlanKernel> {
        let mut out = Vec::with_capacity(self.n_layers);
        for s in &self.steps {
            match &s.op {
                StepOp::Chain(chain) => {
                    for st in &chain.stages {
                        out.push(match st.op {
                            ChainOp::Conv { .. } => PlanKernel::Sliding,
                            ChainOp::Pool { .. } => PlanKernel::Pool,
                        });
                    }
                }
                _ => out.push(s.kernel),
            }
        }
        out
    }

    /// Number of fused chain steps in the plan.
    pub fn fused_steps(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| s.kernel == PlanKernel::FusedChain)
            .count()
    }

    /// Number of model layers covered by fused chain steps.
    pub fn fused_layers(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match &s.op {
                StepOp::Chain(chain) => chain.stages.len(),
                _ => 0,
            })
            .sum()
    }

    /// Autotune audit log: one entry per probed (or cache-served)
    /// conv-shaped layer; empty for heuristic/fixed plans.
    pub fn tuning(&self) -> &[LayerTune] {
        &self.tunes
    }

    /// Segment fuse/no-fuse audit log: one entry per candidate chain
    /// segment probed (or cache-served) under autotune; empty
    /// otherwise.
    pub fn segment_tuning(&self) -> &[SegmentTune] {
        &self.seg_tunes
    }

    /// Human-readable per-layer choices, e.g.
    /// `conv(k=7,c8)→sliding | pool(max)→pool | dense(4)→gemm`; fused
    /// segments print every stage:
    /// `[conv(k=7,c8)+pool(max,w=2)+conv(k=3,c8)]→fused_chain`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .steps
            .iter()
            .map(|s| {
                let shape = match &s.op {
                    StepOp::Conv { p, .. } => format!("conv(k={},c{})", p.k, p.c_out),
                    StepOp::Residual { p } => format!("residual(k={},d={})", p.k, p.dilation),
                    StepOp::Pool { kind, p } => format!("pool({},w={})", kind.name(), p.w),
                    StepOp::Dense { out, .. } => format!("dense({out})"),
                    StepOp::Chain(chain) => {
                        let stages: Vec<String> = chain
                            .stages
                            .iter()
                            .map(|st| match &st.op {
                                ChainOp::Conv { p, .. } => format!("conv(k={},c{})", p.k, p.c_out),
                                ChainOp::Pool { kind, p } => {
                                    format!("pool({},w={})", kind.name(), p.w)
                                }
                            })
                            .collect();
                        format!("[{}]", stages.join("+"))
                    }
                };
                format!("{shape}→{}", s.kernel.name())
            })
            .collect();
        parts.join(" | ")
    }

    // xtask: begin-hot — the plan run path serves requests; allocations
    // below this marker must carry an `alloc-ok:` justification.

    /// Execute on the shared global executor. See
    /// [`Plan::run_with_into`].
    pub fn run_into(
        &self,
        model: &Model,
        x: &[f32],
        scratch: &mut PlanScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        self.run_with_into(Executor::global(), model, x, scratch, out)
    }

    /// Execute the plan: `x` is `[batch, c_in, seq_len]` flattened with
    /// exactly the compiled batch; `out` is resized to the output length
    /// once and fully overwritten. Returns the per-row `(channels, n)`.
    /// `model` must be the model the plan was compiled from (layer
    /// stack is cross-checked). Bit-identical to
    /// [`Model::forward_eager_into`] with the same backend choices.
    pub fn run_with_into(
        &self,
        ex: &Executor,
        model: &Model,
        x: &[f32],
        scratch: &mut PlanScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        ensure!(
            model.layer_count() == self.n_layers,
            "plan compiled for a different model (layer count {} vs {})",
            self.n_layers,
            model.layer_count()
        );
        ensure!(
            x.len() == self.in_len,
            "input length {} != planned batch {} × c_in × seq_len = {}",
            x.len(),
            self.batch,
            self.in_len
        );
        // Grow-only: plans for several batch buckets share one scratch
        // (every consumer takes region prefixes), so a smaller plan must
        // not shrink-then-regrow the arena on every bucket change.
        let arena_len = self.arena_len();
        if scratch.arena.len() < arena_len {
            scratch.arena.resize(arena_len, 0.0);
        }
        if scratch.qbuf.len() < self.qbuf_len {
            scratch.qbuf.resize(self.qbuf_len, 0);
        }
        if scratch.qacc.len() < self.qacc_len {
            scratch.qacc.resize(self.qacc_len, 0);
        }
        out.resize(self.batch * self.out_c * self.out_n, 0.0);
        crate::check::poison(out.as_mut_slice());
        let (reg_a, rest) = scratch.arena.split_at_mut(self.act_len);
        let (reg_b, rest) = rest.split_at_mut(self.act_len);
        let (tmp_reg, rest) = rest.split_at_mut(self.tmp_len);
        let (col_reg, rest) = rest.split_at_mut(self.col_len);
        let (fuse_reg, rest) = rest.split_at_mut(self.fuse_len);
        let pool_reg = &mut rest[..self.pool_len];
        // The activation regions alternate roles per step; the first
        // step reads the request input, the last writes `out`.
        let mut reg_src: &mut [f32] = reg_b;
        let mut reg_dst: &mut [f32] = reg_a;
        let last = self.steps.len() - 1;
        for (i, step) in self.steps.iter().enumerate() {
            {
                let src: &[f32] = if i == 0 { x } else { &reg_src[..step.in_len] };
                let dst: &mut [f32] = if i == last {
                    out.as_mut_slice()
                } else {
                    &mut reg_dst[..step.out_len]
                };
                let qlayer = self.quant.get(step.layer).and_then(|q| q.as_ref());
                exec_step(
                    ex,
                    model,
                    step,
                    src,
                    dst,
                    tmp_reg,
                    col_reg,
                    fuse_reg,
                    pool_reg,
                    qlayer,
                    &mut scratch.qbuf,
                    &mut scratch.qacc,
                )?;
            }
            std::mem::swap(&mut reg_src, &mut reg_dst);
        }
        crate::check::assert_no_poison(out, "Plan::run_with_into");
        Ok((self.out_c, self.out_n))
    }
}

/// Run one compiled step. `src`/`dst` are the step's activation views
/// (disjoint by the arena layout); `tmp`/`col`/`fuse`/`pool_scratch`
/// are the shared residual, im2col, chain-ring, and dense-pool-row
/// regions.
#[allow(clippy::too_many_arguments)]
fn exec_step(
    ex: &Executor,
    model: &Model,
    step: &Step,
    src: &[f32],
    dst: &mut [f32],
    tmp: &mut [f32],
    col: &mut [f32],
    fuse: &mut [f32],
    pool_scratch: &mut [f32],
    qlayer: Option<&QuantLayer>,
    qbuf: &mut [i8],
    qacc: &mut [i32],
) -> Result<()> {
    if let StepOp::Chain(chain) = &step.op {
        return run_fused_chain(ex, model, chain, src, fuse, dst);
    }
    let layer = &model.layers()[step.layer];
    match (&step.op, layer) {
        (StepOp::Conv { p, relu }, Layer::Conv { w, b, .. }) => {
            let epi = if *relu { Epilogue::Relu } else { Epilogue::None };
            if step.kernel == PlanKernel::QuantizedSliding {
                let Some(ql) = qlayer else {
                    bail!("quantized step {} has no pre-quantized weights", step.layer);
                };
                run_conv_quantized(src, ql, Some(b), p, epi, qbuf, qacc, dst);
                return Ok(());
            }
            run_conv(ex, step.kernel, src, w, Some(b), p, epi, col, dst)
        }
        (StepOp::Residual { p }, Layer::Residual { w1, b1, w2, b2, .. }) => {
            let t = &mut tmp[..step.in_len];
            run_conv(ex, step.kernel, src, w1, Some(b1), p, Epilogue::Relu, col, t)?;
            run_conv(
                ex,
                step.kernel,
                &*t,
                w2,
                Some(b2),
                p,
                Epilogue::ReluAdd(src),
                col,
                dst,
            )
        }
        (StepOp::Pool { kind, p }, Layer::Pool { .. }) => {
            if p.stride > 1 && p.stride < p.w && p.boundary == Boundary::Valid {
                // Strided overlapping windows: dense pass + decimation
                // out of the arena's pool region instead of a per-row
                // Vec (same sweep, bit-identical values).
                pool1d_overlap_strided_with_into(ex, *kind, src, p, pool_scratch, dst);
            } else {
                pool1d_with_into(ex, *kind, src, p, dst);
            }
            Ok(())
        }
        (StepOp::Dense { feat, out, relu }, Layer::Dense { w, b, .. }) => {
            dense_forward(ex, src, w, b, step.in_len / feat, *feat, *out, *relu, dst);
            Ok(())
        }
        _ => bail!(
            "plan step {} does not match the model's layer kind",
            step.layer
        ),
    }
}

/// Where a chain advance writes its final-stage outputs.
///
/// The batch sweep hands out per-channel destination column slices
/// (`Rows`); a streaming session stages the tile into a small planar
/// buffer it then interleaves out to the caller (`Planar`). Both
/// resolve `(channel, first column, length)` to a contiguous segment,
/// so the final-stage kernel call is identical — which is what keeps
/// session steps bit-identical to the batch sweep.
pub(crate) enum ChainDst<'d, 'r> {
    /// Per-channel column slices; `v0` is the conceptual column of each
    /// slice's first element (the unit's span start).
    Rows {
        rows: &'d mut [&'r mut [f32]],
        v0: usize,
    },
    /// Planar `[c_out, cap]` staging rows; `lo` is the conceptual
    /// column of each row's first element.
    Planar {
        buf: &'d mut [f32],
        cap: usize,
        lo: usize,
    },
}

impl ChainDst<'_, '_> {
    /// The segment holding channel `co`, conceptual columns
    /// `[t0, t0 + n)`.
    fn seg(&mut self, co: usize, t0: usize, n: usize) -> &mut [f32] {
        match self {
            ChainDst::Rows { rows, v0 } => &mut rows[co][t0 - *v0..][..n],
            ChainDst::Planar { buf, cap, lo } => &mut buf[co * *cap + (t0 - *lo)..][..n],
        }
    }
}

/// Run one chain stage's kernel over conceptual output columns
/// `[new_lo, new_lo + n_new)`, resolving the stage's weights inline
/// from the model (the pairing was validated when the chain/stream was
/// built, so a mismatch here is unreachable). Same row-tile conv body
/// and non-overlapping pool fold as the unfused plan — bit-identity
/// hinges on dispatching to exactly these kernels.
#[allow(clippy::too_many_arguments)]
fn chain_run_stage(
    st: &ChainStage,
    model: &Model,
    src_view: &[f32],
    src0: usize,
    pitch: usize,
    new_lo: usize,
    n_new: usize,
    dst: &mut ChainDst<'_, '_>,
) {
    match (&st.op, &model.layers()[st.layer]) {
        (ChainOp::Conv { p, relu }, Layer::Conv { w, b, .. }) => {
            let epi = if *relu { Epilogue::Relu } else { Epilogue::None };
            for co in 0..st.c_out {
                conv::conv1d_sliding_row_tile_into(
                    dst.seg(co, new_lo, n_new),
                    new_lo,
                    co,
                    src_view,
                    src0,
                    pitch,
                    w.as_slice(),
                    Some(b.as_slice()),
                    p,
                    epi,
                    0,
                );
            }
        }
        (ChainOp::Pool { kind, p }, Layer::Pool { .. }) => {
            for ch in 0..st.c_out {
                let xin = &src_view[ch * pitch..][..pitch];
                pool1d_row_nonoverlap_tile(*kind, xin, src0, p, new_lo, dst.seg(ch, new_lo, n_new));
            }
        }
        _ => unreachable!("chain stage/layer pairing validated at build"),
    }
}

/// Execute a fused chain step: workers sweep `(batch element ×
/// final-column span)` units tile-by-tile through the whole segment,
/// each stage writing into a small per-worker ring buffer in the
/// arena's fuse region and keeping the trailing halo of its input so
/// the next tile resumes without recompute. The per-element math is the
/// *same* row-tile conv body and the *same* non-overlapping pool fold
/// the unfused plan runs, and every final output element is produced by
/// exactly one unit — so results are bit-identical to the unfused plan
/// for every tile size, span split, and thread count (spans restart
/// their halos, which only re-derives identical intermediate values at
/// the boundary).
fn run_fused_chain(
    ex: &Executor,
    model: &Model,
    chain: &ChainPlan,
    src: &[f32],
    fuse: &mut [f32],
    dst: &mut [f32],
) -> Result<()> {
    let stages = &chain.stages;
    let m = stages.len();
    // Validate the stage ↔ layer pairing up front; the sweep resolves
    // weights inline per tile and treats a mismatch as unreachable.
    for st in stages {
        ensure!(
            matches!(
                (&st.op, &model.layers()[st.layer]),
                (ChainOp::Conv { .. }, Layer::Conv { .. })
                    | (ChainOp::Pool { .. }, Layer::Pool { .. })
            ),
            "fused-chain stage {} does not match the model's layer kind",
            st.layer
        );
    }
    let batch = chain.batch;
    let (c_final, n_final) = (stages[m - 1].c_out, stages[m - 1].n_out);
    debug_assert_eq!(src.len(), batch * stages[0].c_in * stages[0].n_in);
    debug_assert_eq!(dst.len(), batch * c_final * n_final);
    // Work partitioning: one unit per (batch element, column span).
    // Spans only split when the batch alone cannot feed the pool; each
    // concurrent ring-buffer set is bounded by the compile-time
    // `max_tasks`, with multiple units run sequentially per task.
    let threads = ex.threads();
    let target = threads.min(CHAIN_MAX_TASKS);
    // Gate on the segment's *total* output volume: a deep
    // down-sampling chain does most of its work in early stages, so the
    // final stage's volume alone would serialize sweeps that are well
    // worth fanning out.
    let small = batch * chain.unit_work < PAR_MIN_FANOUT;
    let spans = if threads <= 1 || small || batch >= target {
        1
    } else {
        target
            .div_ceil(batch)
            .min(n_final.div_ceil(CHAIN_MIN_SPAN))
            .max(1)
    };
    let units = batch * spans;
    let tasks = if threads <= 1 || small {
        1
    } else {
        units.min(target)
    }
    .min(chain.max_tasks)
    .max(1);
    let span_len = n_final.div_ceil(spans);
    // Carve per-unit, per-channel destination column slices. Iterating
    // (batch, channel, span) walks `dst` front to back with no gaps, so
    // sequential `split_at_mut` hands every unit its disjoint columns.
    // alloc-ok: per-unit dst slice table, O(units·c_final) fan-out setup.
    let mut unit_dst: Vec<Vec<&mut [f32]>> =
        (0..units).map(|_| Vec::with_capacity(c_final)).collect();
    {
        let mut rest: &mut [f32] = dst;
        for b in 0..batch {
            for _co in 0..c_final {
                for j in 0..spans {
                    let s0 = (j * span_len).min(n_final);
                    let s1 = ((j + 1) * span_len).min(n_final);
                    let rem = rest;
                    let (piece, tail) = rem.split_at_mut(s1 - s0);
                    rest = tail;
                    unit_dst[b * spans + j].push(piece);
                }
            }
        }
        debug_assert!(rest.is_empty());
    }
    let fuse = &mut fuse[..tasks * chain.task_elems];
    let tile = chain.tile;
    // alloc-ok: one job closure per task (fan-out setup).
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(tasks);
    let mut bufs = fuse.chunks_mut(chain.task_elems);
    let mut unit_iter = unit_dst.into_iter().enumerate();
    let mut assigned = 0usize;
    for ti in 0..tasks {
        let take = (units - assigned).div_ceil(tasks - ti);
        // alloc-ok: this task's unit assignment, O(units) across all tasks.
        let my_units: Vec<(usize, Vec<&mut [f32]>)> = unit_iter.by_ref().take(take).collect();
        assigned += take;
        let buf = bufs.next().expect("one ring-buffer set per task");
        // alloc-ok: job closure box, amortized over a whole unit sweep.
        jobs.push(Box::new(move || {
            for (uidx, mut dsl) in my_units {
                let b = uidx / spans;
                let j = uidx % spans;
                let v0 = (j * span_len).min(n_final);
                let v1 = ((j + 1) * span_len).min(n_final);
                if v0 >= v1 {
                    continue;
                }
                chain_sweep_unit(stages, model, tile, src, b, v0, v1, buf, &mut dsl);
            }
        }));
    }
    ex.scope(jobs);
    Ok(())
}

/// Sweep one `(batch element, final-column span)` unit through the
/// whole segment. Per tile, targets propagate back through the halo
/// geometry ([`ChainStage::in_hi`]) and stages then produce front to
/// back: drop what the next stage has consumed (shifting the retained
/// `extent − stride` halo to the ring-buffer front), append the new
/// rows, hand off. Every stage resumes exactly where it stopped, so
/// nothing is recomputed within a span and the dense intermediates
/// never exist.
#[allow(clippy::too_many_arguments)]
fn chain_sweep_unit(
    stages: &[ChainStage],
    model: &Model,
    tile: usize,
    src: &[f32],
    b: usize,
    v0: usize,
    v1: usize,
    task_buf: &mut [f32],
    dst: &mut [&mut [f32]],
) {
    let m = stages.len();
    let row0 = stages[0].c_in * stages[0].n_in;
    let src_b = &src[b * row0..][..row0];
    // prod[i]: outputs produced so far; lo[i]: conceptual origin of
    // stage i's ring buffer (content = [lo, prod)); hi[i]: per-advance
    // production target.
    let mut prod: Vec<usize> = vec![0; m]; // alloc-ok: O(stages) cursors
    let mut lo: Vec<usize> = vec![0; m]; // alloc-ok: O(stages) cursors
    let mut hi: Vec<usize> = vec![0; m]; // alloc-ok: O(stages) cursors
    prod[m - 1] = v0;
    for i in (0..m - 1).rev() {
        prod[i] = stages[i + 1].in_lo(prod[i + 1]);
        lo[i] = prod[i];
    }
    let mut u = v0;
    while u < v1 {
        let u1 = (u + tile).min(v1);
        chain_advance(
            stages,
            model,
            src_b,
            0,
            stages[0].n_in,
            task_buf,
            &mut prod,
            &mut lo,
            &mut hi,
            u1,
            ChainDst::Rows {
                rows: &mut *dst,
                v0,
            },
        );
        u = u1;
    }
}

/// Advance every stage of a chain far enough to bring the final stage
/// from `prod[m-1]` up to `u1` final outputs — one tile of the batch
/// sweep, or one packet of a streaming session. Targets propagate back
/// through the halo geometry ([`ChainStage::in_hi`]) and stages then
/// produce front to back: drop what the next stage has consumed
/// (shifting the retained `extent − stride` halo to the ring-buffer
/// front), append the new rows, hand off. Every stage resumes exactly
/// where it stopped — nothing is recomputed and the dense
/// intermediates never exist.
///
/// `src`/`src0`/`pitch0` describe stage 0's input rows: a view whose
/// per-channel rows (pitch `pitch0`) start at conceptual column `src0`
/// and must cover every column `[in_lo(new_lo), in_hi(u1-target))`
/// stage 0 still needs — the full input row for the batch sweep, the
/// session's input ring otherwise. `task_buf` holds the per-stage ring
/// buffers laid out by [`ChainStage::buf_off`]; `prod`/`lo`/`hi` are
/// the resume cursors (callers zero them at conceptual origin v0 = 0,
/// or back-solve via [`ChainStage::in_lo`] for a mid-row span start).
///
/// Performs no allocation: per-stage ring views are carved out of
/// `task_buf` by offset on the fly, and weights resolve inline from
/// `model` — this is what lets a session step run allocation-free.
#[allow(clippy::too_many_arguments)]
pub(crate) fn chain_advance(
    stages: &[ChainStage],
    model: &Model,
    src: &[f32],
    src0: usize,
    pitch0: usize,
    task_buf: &mut [f32],
    prod: &mut [usize],
    lo: &mut [usize],
    hi: &mut [usize],
    u1: usize,
    dst: ChainDst<'_, '_>,
) {
    let m = stages.len();
    hi[m - 1] = u1;
    for i in (0..m - 1).rev() {
        hi[i] = stages[i + 1].in_hi(hi[i + 1]).max(prod[i]);
    }
    let mut final_dst = Some(dst);
    for i in 0..m {
        // Drop fully consumed input rows: the next stage resumes at
        // prod[i+1], so everything below its in_lo is dead. A
        // stride > extent stage (gapped pool) can leave lo ahead of
        // prod — the gap elements are simply never produced.
        if i + 1 < m {
            let keep = stages[i + 1].in_lo(prod[i + 1]);
            if keep > lo[i] {
                let have = prod[i].saturating_sub(keep);
                if have > 0 {
                    let shift = keep - lo[i];
                    let cap = stages[i].cap;
                    crate::invariant!(
                        shift + have <= cap,
                        "chain halo shift out of ring bounds at stage {i}"
                    );
                    let ring = &mut task_buf[stages[i].buf_off..][..stages[i].c_out * cap];
                    for row in ring.chunks_mut(cap) {
                        row.copy_within(shift..shift + have, 0);
                    }
                }
                lo[i] = keep;
            }
        }
        let new_lo = if i + 1 < m {
            prod[i].max(lo[i])
        } else {
            prod[i]
        };
        let new_hi = hi[i];
        if new_hi <= new_lo {
            prod[i] = prod[i].max(new_hi);
            continue;
        }
        let n_new = new_hi - new_lo;
        crate::invariant!(
            i + 1 == m || new_hi - lo[i] <= stages[i].cap,
            "chain ring-buffer overflow at stage {i}"
        );
        // Rings live in `task_buf` in stage order, so one split at this
        // stage's offset separates its input ring (behind) from its
        // output ring (ahead) without aliasing — no view table needed.
        if i + 1 < m {
            let (behind, ahead) = task_buf.split_at_mut(stages[i].buf_off);
            let (src_view, sv0, pitch): (&[f32], usize, usize) = if i == 0 {
                (src, src0, pitch0)
            } else {
                (
                    &behind[stages[i - 1].buf_off..][..stages[i - 1].c_out * stages[i - 1].cap],
                    lo[i - 1],
                    stages[i - 1].cap,
                )
            };
            let ring = &mut ahead[..stages[i].c_out * stages[i].cap];
            let mut sdst = ChainDst::Planar {
                buf: ring,
                cap: stages[i].cap,
                lo: lo[i],
            };
            chain_run_stage(&stages[i], model, src_view, sv0, pitch, new_lo, n_new, &mut sdst);
        } else {
            let (src_view, sv0, pitch): (&[f32], usize, usize) = if i == 0 {
                (src, src0, pitch0)
            } else {
                (
                    &task_buf[stages[i - 1].buf_off..][..stages[i - 1].c_out * stages[i - 1].cap],
                    lo[i - 1],
                    stages[i - 1].cap,
                )
            };
            let mut sdst = final_dst.take().expect("final stage runs once per advance");
            chain_run_stage(&stages[i], model, src_view, sv0, pitch, new_lo, n_new, &mut sdst);
        }
        prod[i] = new_hi;
    }
}

// xtask: end-hot — probing/compile helpers below allocate freely.

/// Measure a candidate segment fused vs unfused (compile-time only;
/// decisions cached process-wide in the [`TuneCache`], and on disk when
/// persistence is on). Fused wins ties — it also shrinks the arena.
fn probe_segment(
    ex: &Executor,
    model: &Model,
    chain: &ChainPlan,
    raw: &[Step],
    seg_tunes: &mut Vec<SegmentTune>,
) -> Result<bool> {
    let key: SegKey = (segment_sig(chain), crate::simd::tier(), ex.threads());
    let layers = (raw[0].layer, raw[raw.len() - 1].layer);
    if let Some(fused) = TuneCache::global().lookup_segment(&key) {
        seg_tunes.push(SegmentTune {
            layers,
            fused,
            cached: true,
            fused_micros: 0.0,
            unfused_micros: 0.0,
        });
        return Ok(fused);
    }
    // Probe buffers (allocating is fine here — never on the request
    // path). Deterministic non-zero input, same pattern as the kernel
    // probes.
    let x: Vec<f32> = (0..raw[0].in_len)
        .map(|i| ((i % 29) as f32) * 0.0625 - 0.875)
        .collect();
    let mut outs: Vec<Vec<f32>> = raw.iter().map(|s| vec![0.0f32; s.out_len]).collect();
    let mut fuse_buf = vec![0.0f32; chain.max_tasks * chain.task_elems];
    let mut out = vec![0.0f32; raw[raw.len() - 1].out_len];
    exec_segment_unfused(ex, model, raw, &x, &mut outs)?;
    let mut unfused_best = f64::INFINITY;
    for _ in 0..PROBE_ITERS {
        let t0 = Instant::now();
        exec_segment_unfused(ex, model, raw, &x, &mut outs)?;
        unfused_best = unfused_best.min(t0.elapsed().as_secs_f64() * 1e6);
    }
    run_fused_chain(ex, model, chain, &x, &mut fuse_buf, &mut out)?;
    let mut fused_best = f64::INFINITY;
    for _ in 0..PROBE_ITERS {
        let t0 = Instant::now();
        run_fused_chain(ex, model, chain, &x, &mut fuse_buf, &mut out)?;
        fused_best = fused_best.min(t0.elapsed().as_secs_f64() * 1e6);
    }
    let fused = fused_best <= unfused_best;
    let canonical = TuneCache::global().insert_segment(key, fused);
    seg_tunes.push(SegmentTune {
        layers,
        fused: canonical,
        cached: false,
        fused_micros: fused_best,
        unfused_micros: unfused_best,
    });
    Ok(canonical)
}

/// Run a candidate segment's raw steps sequentially (the unfused probe
/// arm): per-step buffers, same kernels the unfused plan would run.
fn exec_segment_unfused(
    ex: &Executor,
    model: &Model,
    raw: &[Step],
    x: &[f32],
    outs: &mut [Vec<f32>],
) -> Result<()> {
    for (si, s) in raw.iter().enumerate() {
        let (head, tail) = outs.split_at_mut(si);
        let src: &[f32] = if si == 0 { x } else { &head[si - 1] };
        let dst: &mut [f32] = &mut tail[0];
        match &s.op {
            StepOp::Conv { p, relu } => {
                let Layer::Conv { w, b, .. } = &model.layers()[s.layer] else {
                    bail!("segment probe: layer {} is not a conv", s.layer);
                };
                let epi = if *relu { Epilogue::Relu } else { Epilogue::None };
                run_conv(ex, s.kernel, src, w, Some(b), p, epi, &mut [], dst)?;
            }
            StepOp::Pool { kind, p } => pool1d_with_into(ex, *kind, src, p, dst),
            _ => bail!("non-chainable step in segment probe"),
        }
    }
    Ok(())
}

/// Stable signature of a segment's stage shapes (plus batch and the
/// *effective* tile size) for the [`TuneCache`] — uses only JSON-safe
/// characters so persisted keys round-trip verbatim. The tile is part
/// of the key because a decision measured under a forced tiny tile
/// (`PlannerConfig::chain_tile`, which pays per-tile bookkeeping on
/// every column) must never answer for a default cache-sized compile;
/// the auto-sized tile is a pure function of the stage shapes, so
/// default compiles still collide onto one key.
fn segment_sig(chain: &ChainPlan) -> String {
    use std::fmt::Write;
    let mut s = format!("b{}t{}", chain.batch, chain.tile);
    for st in &chain.stages {
        match &st.op {
            ChainOp::Conv { p, relu } => {
                let _ = write!(
                    s,
                    "+conv_ci{}co{}n{}k{}s{}d{}p{}r{}",
                    p.c_in, p.c_out, p.n, p.k, p.stride, p.dilation, p.pad, *relu as u8
                );
            }
            ChainOp::Pool { kind, p } => {
                let _ = write!(
                    s,
                    "+pool_{}c{}n{}w{}s{}",
                    kind.name(),
                    p.channels,
                    p.n,
                    p.w,
                    p.stride
                );
            }
        }
    }
    s
}

// xtask: begin-hot — per-step conv dispatch runs on the request path.

/// Dispatch a conv-shaped step to its chosen kernel, epilogue fused.
#[allow(clippy::too_many_arguments)]
fn run_conv(
    ex: &Executor,
    kernel: PlanKernel,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    col: &mut [f32],
    y: &mut [f32],
) -> Result<()> {
    match kernel {
        PlanKernel::Sliding => conv::conv1d_sliding_with_into(ex, x, w, bias, p, epi, y),
        PlanKernel::Im2col => conv::conv1d_im2col_epilogue_into(ex, x, w, bias, p, epi, col, y),
        PlanKernel::SmallK => {
            ensure!(
                conv::conv1d_small_k_into(x, w, bias, p, epi, y),
                "planner selected small_k for a non-qualifying shape"
            );
        }
        PlanKernel::Direct => {
            conv::conv1d_direct_into(x, w, bias, p, y);
            epi.apply(y, 0);
        }
        PlanKernel::SlidingPair => {
            let v = conv::conv1d_pair(x, w, bias, p);
            y.copy_from_slice(&v);
            epi.apply(y, 0);
        }
        PlanKernel::QuantizedSliding => {
            bail!("quantized steps resolve through the plan's QuantLayer, not run_conv")
        }
        PlanKernel::Gemm | PlanKernel::Pool | PlanKernel::FusedChain => {
            bail!("non-conv kernel {} in a conv step", kernel.name())
        }
    }
    Ok(())
}

/// Execute one int8 conv step: scan the f32 activations for their
/// dynamic range, quantize them into the plan's i8 scratch, and run the
/// quantized sliding kernel over pre-quantized weights. Serial over
/// `(batch, c_out)` rows and pure i32 inside, so output is
/// bit-identical across thread counts *and* SIMD tiers.
#[allow(clippy::too_many_arguments)]
fn run_conv_quantized(
    x: &[f32],
    ql: &QuantLayer,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    qbuf: &mut [i8],
    qacc: &mut [i32],
    y: &mut [f32],
) {
    let x_params = conv::QuantParams::from_slice(x);
    let qx = &mut qbuf[..x.len()];
    x_params.quantize_slice_into(x, qx);
    conv::conv1d_quantized_into(qx, &ql.qw, x_params, ql.w_params, bias, p, epi, qacc, y);
}

// xtask: end-hot

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{load_config, LayerConfig, ModelConfig};
    use crate::workload::Rng;

    const CFG: &str = r#"
[model]
name = "plan_t"
c_in = 1
seq_len = 64

[layer.0]
type = "conv"
c_out = 8
k = 7

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "dense"
out = 3
"#;

    fn model() -> Model {
        let (mc, _) = load_config(CFG).unwrap();
        Model::init(&mc, &mut Rng::new(7)).unwrap()
    }

    #[test]
    fn compile_resolves_every_layer() {
        let m = model();
        let plan = Plan::compile(&m, 4, &PlannerConfig::default()).unwrap();
        assert_eq!(plan.batch(), 4);
        // The pool follows a residual, not a conv, so nothing fuses.
        assert_eq!(plan.kernels().len(), 4);
        assert_eq!(plan.fused_steps(), 0);
        assert_eq!(plan.kernels()[2], PlanKernel::Pool);
        assert_eq!(plan.kernels()[3], PlanKernel::Gemm);
        assert_eq!(plan.layer_kernels(), plan.kernels());
        assert!(plan.arena_len() > 0);
        assert!(plan.describe().contains("dense(3)→gemm"), "{}", plan.describe());
    }

    #[test]
    fn fixed_backend_maps_every_conv_layer() {
        let m = model();
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Im2colGemm),
            ..PlannerConfig::default()
        };
        let plan = Plan::compile(&m, 1, &cfg).unwrap();
        assert_eq!(plan.kernels()[0], PlanKernel::Im2col);
        assert_eq!(plan.kernels()[1], PlanKernel::Im2col);
        assert!(plan.col_len > 0, "im2col layers reserve a column region");
    }

    #[test]
    fn planned_run_matches_forward() {
        let m = model();
        let mut rng = Rng::new(9);
        for batch in [1usize, 3] {
            let x = rng.vec_uniform(batch * 64, -1.0, 1.0);
            let want = m.forward(&x, batch, ConvBackend::Sliding).unwrap();
            let cfg = PlannerConfig {
                backend: BackendChoice::Fixed(ConvBackend::Sliding),
                ..PlannerConfig::default()
            };
            let plan = Plan::compile(&m, batch, &cfg).unwrap();
            let mut scratch = PlanScratch::default();
            let mut out = Vec::new();
            let (c, n) = plan.run_into(&m, &x, &mut scratch, &mut out).unwrap();
            assert_eq!((c, n), m.out_shape());
            assert_eq!(out, want.data, "batch {batch}");
        }
    }

    #[test]
    fn wrong_batch_rejected() {
        let m = model();
        let plan = Plan::compile(&m, 2, &PlannerConfig::default()).unwrap();
        let mut scratch = PlanScratch::default();
        let mut out = Vec::new();
        assert!(plan.run_into(&m, &[0.0; 64], &mut scratch, &mut out).is_err());
    }

    #[test]
    fn cost_model_prefers_small_k_and_sliding() {
        // Single-channel k=3 → small_k.
        let p = Conv1dParams::new(1, 1, 1024, 3);
        assert_eq!(choose_kernel(&p), PlanKernel::SmallK);
        // Large filter → sliding.
        let p = Conv1dParams::new(1, 1, 1024, 63);
        assert_eq!(choose_kernel(&p), PlanKernel::Sliding);
        // Fat channel reduction with a tiny receptive field → im2col.
        let p = Conv1dParams::new(16, 32, 1024, 3).with_same_pad();
        assert_eq!(choose_kernel(&p), PlanKernel::Im2col);
        // Same reduction but dilated far → sliding again.
        let p = Conv1dParams::new(16, 32, 1024, 3).with_dilation(8).with_same_pad();
        assert_eq!(choose_kernel(&p), PlanKernel::Sliding);
    }

    /// Pin every decision boundary of the shape heuristic so the
    /// autotuner can evolve without silently shifting the probe-free
    /// fallback (`c_out ≥ 8`, `c_in·k ≥ 48`, `eff_k ≤ 9`, small-k
    /// qualification).
    #[test]
    fn choose_kernel_decision_boundaries_pinned() {
        let base = |c_in: usize, c_out: usize, k: usize| Conv1dParams::new(c_in, c_out, 4096, k);
        // c_in·k = 48 exactly, c_out = 8 exactly, eff_k = 3 → im2col.
        assert_eq!(choose_kernel(&base(16, 8, 3)), PlanKernel::Im2col);
        // One below the c_out boundary.
        assert_eq!(choose_kernel(&base(16, 7, 3)), PlanKernel::Sliding);
        // One below the reduction boundary (45 < 48).
        assert_eq!(choose_kernel(&base(15, 8, 3)), PlanKernel::Sliding);
        // eff_k = 9 exactly still qualifies (6·9 = 54 ≥ 48).
        assert_eq!(choose_kernel(&base(6, 8, 9)), PlanKernel::Im2col);
        // eff_k = 10 does not.
        assert_eq!(choose_kernel(&base(6, 8, 10)), PlanKernel::Sliding);
        // Dilation pushes the receptive field over the boundary:
        // (3−1)·4+1 = 9 qualifies, (3−1)·5+1 = 11 does not.
        assert_eq!(
            choose_kernel(&base(16, 8, 3).with_dilation(4)),
            PlanKernel::Im2col
        );
        assert_eq!(
            choose_kernel(&base(16, 8, 3).with_dilation(5)),
            PlanKernel::Sliding
        );
        // Small-k qualification: single channel, unit stride/dilation,
        // no padding, k ∈ {3, 5}.
        assert_eq!(choose_kernel(&base(1, 1, 5)), PlanKernel::SmallK);
        assert_eq!(choose_kernel(&base(1, 1, 7)), PlanKernel::Sliding);
        assert_eq!(
            choose_kernel(&base(1, 1, 3).with_stride(2)),
            PlanKernel::Sliding
        );
        assert_eq!(
            choose_kernel(&base(1, 1, 3).with_dilation(2)),
            PlanKernel::Sliding
        );
        assert_eq!(
            choose_kernel(&base(1, 1, 3).with_same_pad()),
            PlanKernel::Sliding
        );
        assert_eq!(choose_kernel(&base(2, 1, 3)), PlanKernel::Sliding);
    }

    #[test]
    fn chain_fusion_groups_maximal_runs() {
        const FUSE_CFG: &str = r#"
[model]
name = "fuse_t"
c_in = 1
seq_len = 96

[layer.0]
type = "conv"
c_out = 4
k = 5

[layer.1]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.2]
type = "conv"
c_out = 4
k = 3

[layer.3]
type = "pool"
kind = "avg"
w = 3
stride = 2
"#;
        let (mc, _) = load_config(FUSE_CFG).unwrap();
        let m = Model::init(&mc, &mut Rng::new(5)).unwrap();
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Sliding),
            ..PlannerConfig::default()
        };
        let plan = Plan::compile(&m, 2, &cfg).unwrap();
        // Layers 0–2 (conv, non-overlapping pool, conv) are one maximal
        // run → one chain; layer 3 (overlapping windows, stride < w)
        // breaks the segment and stays a lone pool step.
        assert_eq!(plan.fused_steps(), 1, "{}", plan.describe());
        assert_eq!(plan.fused_layers(), 3, "{}", plan.describe());
        assert_eq!(
            plan.kernels(),
            vec![PlanKernel::FusedChain, PlanKernel::Pool],
            "{}",
            plan.describe()
        );
        assert_eq!(
            plan.layer_kernels(),
            vec![
                PlanKernel::Sliding,
                PlanKernel::Pool,
                PlanKernel::Sliding,
                PlanKernel::Pool
            ]
        );
        assert!(plan.fuse_len > 0, "fused chain reserves ring buffers");
        assert!(
            plan.pool_len > 0,
            "overlapping strided pool reserves dense scratch"
        );
        assert!(
            plan.describe()
                .contains("[conv(k=5,c4)+pool(max,w=2)+conv(k=3,c4)]→fused_chain"),
            "{}",
            plan.describe()
        );

        // Fusion off → one step per layer, no fuse region.
        let unfused = Plan::compile(
            &m,
            2,
            &PlannerConfig {
                fuse: false,
                ..cfg
            },
        )
        .unwrap();
        assert_eq!(unfused.fused_steps(), 0);
        assert_eq!(unfused.kernels().len(), 4);
        assert_eq!(unfused.fuse_len, 0);

        // Fused and unfused runs are bit-identical (and match eager).
        let mut rng = Rng::new(11);
        let x = rng.vec_uniform(2 * 96, -1.0, 1.0);
        let mut scratch = PlanScratch::default();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        plan.run_into(&m, &x, &mut scratch, &mut a).unwrap();
        unfused.run_into(&m, &x, &mut scratch, &mut b).unwrap();
        assert_eq!(a, b, "fused plan diverged from unfused plan");
        let mut want = Vec::new();
        m.forward_eager_into(
            &x,
            2,
            ConvBackend::Sliding,
            &mut crate::nn::EagerScratch::default(),
            &mut want,
        )
        .unwrap();
        assert_eq!(a, want, "fused plan diverged from eager");
    }

    /// Boundary pin for the segment-break rules: residual skips,
    /// non-sliding kernels (per-layer overrides), and overlapping pools
    /// all end a chain; adjacent eligible layers always group.
    #[test]
    fn chain_segment_break_rules_pinned() {
        let conv = |backend| LayerConfig::Conv {
            c_out: 3,
            k: 3,
            stride: 1,
            dilation: 1,
            same_pad: true,
            relu: true,
            backend,
            quantize: false,
        };
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Sliding),
            ..PlannerConfig::default()
        };
        let compile = |layers: Vec<LayerConfig>| {
            let mc = ModelConfig {
                name: "breaks".into(),
                c_in: 1,
                seq_len: 64,
                layers,
            };
            // c_in 1 vs conv c_out 3: first conv takes c_in from the
            // model, residuals preserve channels.
            let m = Model::init(&mc, &mut Rng::new(9)).unwrap();
            Plan::compile(&m, 2, &cfg).unwrap()
        };
        // conv→conv fuses.
        let p = compile(vec![conv(None), conv(None)]);
        assert_eq!(p.kernels(), vec![PlanKernel::FusedChain], "{}", p.describe());
        assert_eq!(p.fused_layers(), 2);
        // A residual between them breaks the run (and 1-layer runs
        // never fuse).
        let p = compile(vec![
            conv(None),
            LayerConfig::Residual { k: 3, dilation: 1, backend: None },
            conv(None),
        ]);
        assert_eq!(p.fused_steps(), 0, "{}", p.describe());
        assert_eq!(p.kernels().len(), 3);
        // A non-sliding per-layer override breaks the run.
        let p = compile(vec![conv(Some(ConvBackend::Im2colGemm)), conv(None)]);
        assert_eq!(p.fused_steps(), 0, "{}", p.describe());
        // An overlapping pool (stride < w) breaks the run.
        let p = compile(vec![
            conv(None),
            LayerConfig::Pool { kind: "max".into(), w: 3, stride: 2 },
            conv(None),
        ]);
        assert_eq!(p.fused_steps(), 0, "{}", p.describe());
        // A lone pool run (no conv) never fuses.
        let p = compile(vec![
            LayerConfig::Pool { kind: "max".into(), w: 2, stride: 2 },
            LayerConfig::Pool { kind: "avg".into(), w: 2, stride: 2 },
        ]);
        assert_eq!(p.fused_steps(), 0, "{}", p.describe());
        // conv→pool→conv→pool→conv is one chain of five.
        let pool = || LayerConfig::Pool { kind: "max".into(), w: 2, stride: 2 };
        let p = compile(vec![conv(None), pool(), conv(None), pool(), conv(None)]);
        assert_eq!(p.kernels(), vec![PlanKernel::FusedChain], "{}", p.describe());
        assert_eq!(p.fused_layers(), 5);
    }

    /// The sweep is bit-identical for every tile size — forced tiny
    /// tiles exercise the halo handoff on every stage boundary.
    #[test]
    fn chain_forced_tile_sizes_bit_identical() {
        const CFG_T: &str = r#"
[model]
name = "tiles"
c_in = 2
seq_len = 80

[layer.0]
type = "conv"
c_out = 4
k = 7

[layer.1]
type = "conv"
c_out = 3
k = 5
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "conv"
c_out = 2
k = 3
relu = false
"#;
        let (mc, _) = load_config(CFG_T).unwrap();
        let m = Model::init(&mc, &mut Rng::new(17)).unwrap();
        let base = PlannerConfig {
            backend: BackendChoice::Fixed(ConvBackend::Sliding),
            ..PlannerConfig::default()
        };
        let mut rng = Rng::new(18);
        let x = rng.vec_uniform(3 * 2 * 80, -1.0, 1.0);
        let mut scratch = PlanScratch::default();
        let mut want = Vec::new();
        Plan::compile(&m, 3, &PlannerConfig { fuse: false, ..base })
            .unwrap()
            .run_into(&m, &x, &mut scratch, &mut want)
            .unwrap();
        for tile in [1usize, 2, 3, 7, 16, 1000] {
            let plan = Plan::compile(
                &m,
                3,
                &PlannerConfig {
                    chain_tile: Some(tile),
                    ..base
                },
            )
            .unwrap();
            assert_eq!(plan.fused_steps(), 1, "{}", plan.describe());
            assert_eq!(plan.fused_layers(), 4, "{}", plan.describe());
            let mut got = Vec::new();
            plan.run_into(&m, &x, &mut scratch, &mut got).unwrap();
            assert_eq!(got, want, "tile {tile}");
        }
        // Auto-sized tile too.
        let plan = Plan::compile(&m, 3, &base).unwrap();
        let mut got = Vec::new();
        plan.run_into(&m, &x, &mut scratch, &mut got).unwrap();
        assert_eq!(got, want, "auto tile");
    }

    /// The strided overlapping pool runs out of the arena's pool region
    /// on the plan path and stays bit-identical to the eager path.
    #[test]
    fn overlap_strided_pool_uses_arena_scratch() {
        const CFG_P: &str = r#"
[model]
name = "opool"
c_in = 3
seq_len = 90

[layer.0]
type = "pool"
kind = "avg"
w = 4
stride = 2
"#;
        let (mc, _) = load_config(CFG_P).unwrap();
        let m = Model::init(&mc, &mut Rng::new(3)).unwrap();
        let plan = Plan::compile(&m, 2, &PlannerConfig::default()).unwrap();
        assert!(plan.pool_len > 0, "dense scratch reserved in the arena");
        let mut rng = Rng::new(4);
        let x = rng.vec_uniform(2 * 3 * 90, -1.0, 1.0);
        let mut got = Vec::new();
        plan.run_into(&m, &x, &mut PlanScratch::default(), &mut got)
            .unwrap();
        let mut want = Vec::new();
        m.forward_eager_into(
            &x,
            2,
            ConvBackend::Sliding,
            &mut crate::nn::EagerScratch::default(),
            &mut want,
        )
        .unwrap();
        assert_eq!(got, want, "arena-scratch pool diverged from eager");
    }

    /// Disk persistence round-trip: kernel and segment decisions
    /// survive a save/load cycle on a fresh cache, keyed to this CPU.
    #[test]
    fn tune_cache_persists_and_reloads() {
        let cache = TuneCache::default();
        let key = TuneKey {
            shape: Conv1dParams::new(3, 4, 100, 5).with_batch(2).with_same_pad(),
            tier: SimdTier::Generic,
            threads: 3,
            quant: false,
        };
        assert_eq!(cache.insert(key, PlanKernel::Im2col), PlanKernel::Im2col);
        // The same shape with int8 eligibility is a distinct key and an
        // int8 decision round-trips through the file format.
        let qkey = TuneKey { quant: true, ..key };
        assert_eq!(
            cache.insert(qkey, PlanKernel::QuantizedSliding),
            PlanKernel::QuantizedSliding
        );
        let seg: SegKey = (
            "b2+conv_ci1co2n64k3s1d1p0r1+pool_maxc2n62w2s2".into(),
            SimdTier::Generic,
            3,
        );
        assert!(cache.insert_segment(seg.clone(), true));
        let path = std::env::temp_dir().join(format!(
            "swsnn_tunecache_test_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        cache.save_to(&path).unwrap();
        let fresh = TuneCache::default();
        assert_eq!(fresh.load_from(&path).unwrap(), 3, "all entries merge");
        assert_eq!(fresh.lookup(&key), Some(PlanKernel::Im2col));
        assert_eq!(fresh.lookup(&qkey), Some(PlanKernel::QuantizedSliding));
        assert_eq!(fresh.lookup_segment(&seg), Some(true));
        // A different machine configuration (threads) still misses.
        let other = TuneKey { threads: 4, ..key };
        assert_eq!(fresh.lookup(&other), None);
        // Re-loading is idempotent (no duplicates).
        assert_eq!(fresh.load_from(&path).unwrap(), 0);
        // In-memory decisions win over a conflicting file.
        let conflicting = TuneCache::default();
        conflicting.insert(key, PlanKernel::Direct);
        conflicting.load_from(&path).unwrap();
        assert_eq!(conflicting.lookup(&key), Some(PlanKernel::Direct));
        let _ = std::fs::remove_file(&path);
    }

    /// Corrupt-snapshot robustness (property): arbitrary truncations and
    /// byte flips of a valid cache file must never panic `load_from` —
    /// the worst allowed outcome is fewer (or zero) merged entries — and
    /// a cache that just absorbed garbage must still merge a clean file.
    #[test]
    fn tune_cache_load_survives_mangled_json() {
        let cache = TuneCache::default();
        cache.insert(
            TuneKey {
                shape: Conv1dParams::new(2, 3, 80, 3).with_batch(2),
                tier: SimdTier::Generic,
                threads: 2,
                quant: false,
            },
            PlanKernel::Sliding,
        );
        cache.insert_segment(
            ("b1+conv_ci1co1n32k3s1d1p0r0".into(), SimdTier::Generic, 2),
            false,
        );
        let path = std::env::temp_dir().join(format!(
            "swsnn_tunecache_mangle_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        cache.save_to(&path).unwrap();
        let valid = std::fs::read_to_string(&path).unwrap();
        assert_eq!(TuneCache::default().load_from(&path).unwrap(), 2);
        crate::prop::check(
            crate::prop::PropConfig {
                cases: 300,
                ..Default::default()
            },
            "mangled tune cache never panics",
            |g| {
                let mut bytes = valid.clone().into_bytes();
                match g.usize_in(0, 3) {
                    // Truncation (partial write / full disk).
                    0 => bytes.truncate(g.usize_in(0, bytes.len() + 1)),
                    // Byte flips (bit rot, editor damage) — may also
                    // produce invalid UTF-8, which must surface as Err.
                    1 => {
                        for _ in 0..g.usize_in(1, 9) {
                            let i = g.usize_in(0, bytes.len());
                            bytes[i] = g.usize_in(0, 256) as u8;
                        }
                    }
                    // Both at once.
                    _ => {
                        bytes.truncate(g.usize_in(0, bytes.len() + 1));
                        if !bytes.is_empty() {
                            let i = g.usize_in(0, bytes.len());
                            bytes[i] = g.usize_in(0, 256) as u8;
                        }
                    }
                }
                std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
                let fresh = TuneCache::default();
                // Ok (with anything ≤ the real entry count merged) or a
                // clean Err are both acceptable; a panic fails the test.
                let merged = fresh.load_from(&path).unwrap_or(0);
                crate::prop::ensure(merged <= 2, format!("merged {merged} > entries written"))?;
                std::fs::write(&path, valid.as_bytes()).map_err(|e| e.to_string())?;
                let after = fresh.load_from(&path).map_err(|e| e.to_string())?;
                crate::prop::ensure(
                    merged + after >= 2,
                    "clean reload after garbage lost entries",
                )
            },
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fixed_non_sliding_backends_do_not_fuse() {
        const CFG2: &str = r#"
[model]
name = "nofuse"
c_in = 1
seq_len = 64

[layer.0]
type = "conv"
c_out = 4
k = 3

[layer.1]
type = "pool"
kind = "max"
w = 2
stride = 2
"#;
        let (mc, _) = load_config(CFG2).unwrap();
        let m = Model::init(&mc, &mut Rng::new(3)).unwrap();
        for backend in [ConvBackend::Im2colGemm, ConvBackend::Direct] {
            let plan = Plan::compile(
                &m,
                1,
                &PlannerConfig {
                    backend: BackendChoice::Fixed(backend),
                    ..PlannerConfig::default()
                },
            )
            .unwrap();
            assert_eq!(plan.fused_steps(), 0, "{backend:?}");
            assert_eq!(plan.kernels().len(), 2, "{backend:?}");
        }
    }

    #[test]
    fn autotune_records_probes_and_hits_cache_on_recompile() {
        let m = model();
        let cfg = PlannerConfig {
            backend: BackendChoice::Auto,
            autotune: true,
            ..PlannerConfig::default()
        };
        // Uncommon batch so other tests cannot have pre-seeded the keys.
        let plan = Plan::compile(&m, 6, &cfg).unwrap();
        // Two conv-shaped layers (conv + residual) → two tune records.
        assert_eq!(plan.tuning().len(), 2);
        for t in plan.tuning() {
            if !t.cached {
                assert!(
                    t.probes.len() >= 3,
                    "probes cover sliding/im2col/direct at least: {t:?}"
                );
                assert!(t.probes.iter().any(|p| p.kernel == t.chosen));
                assert!(t.probes.iter().all(|p| p.micros.is_finite()));
            }
        }
        // Recompiling the same shapes is served from the TuneCache.
        let again = Plan::compile(&m, 6, &cfg).unwrap();
        assert!(
            again.tuning().iter().all(|t| t.cached),
            "second compile re-probed: {:?}",
            again.tuning()
        );
        assert_eq!(
            plan.tuning().iter().map(|t| t.chosen).collect::<Vec<_>>(),
            again.tuning().iter().map(|t| t.chosen).collect::<Vec<_>>(),
            "cache returned a different decision"
        );
        // Autotuned plans execute like any other plan.
        let mut rng = Rng::new(13);
        let x = rng.vec_uniform(6 * 64, -1.0, 1.0);
        let mut out = Vec::new();
        plan.run_into(&m, &x, &mut PlanScratch::default(), &mut out)
            .unwrap();
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn per_layer_override_bypasses_autotune() {
        const CFG3: &str = r#"
[model]
name = "pinned"
c_in = 1
seq_len = 48

[layer.0]
type = "conv"
c_out = 4
k = 5
backend = "direct"
"#;
        let (mc, _) = load_config(CFG3).unwrap();
        let m = Model::init(&mc, &mut Rng::new(2)).unwrap();
        let plan = Plan::compile(
            &m,
            1,
            &PlannerConfig {
                backend: BackendChoice::Auto,
                autotune: true,
                ..PlannerConfig::default()
            },
        )
        .unwrap();
        assert_eq!(plan.kernels(), vec![PlanKernel::Direct]);
        assert!(plan.tuning().is_empty(), "override must not probe");
    }

    const QCFG: &str = r#"
[model]
name = "quant_t"
c_in = 2
seq_len = 96

[layer.0]
type = "conv"
c_out = 3
k = 5
quantize = "int8"

[layer.1]
type = "conv"
c_out = 2
k = 3
"#;

    /// The per-layer `quantize = "int8"` opt-in compiles that layer — and
    /// only that layer — to the int8 kernel under `Auto` without
    /// autotune, and the quantized plan tracks the f32 reference within
    /// a bound derived from the quantization scales (the accuracy gate).
    #[test]
    fn quantize_opt_in_compiles_int8_and_tracks_f32() {
        let (mc, _) = load_config(QCFG).unwrap();
        let m = Model::init(&mc, &mut Rng::new(21)).unwrap();
        let plan = Plan::compile(&m, 2, &PlannerConfig::default()).unwrap();
        let kernels = plan.layer_kernels();
        assert_eq!(kernels[0], PlanKernel::QuantizedSliding, "{}", plan.describe());
        assert_ne!(kernels[1], PlanKernel::QuantizedSliding, "opt-in is per-layer");
        assert!(plan.qbuf_len > 0 && plan.qacc_len > 0, "quantized scratch reserved");
        assert!(plan.describe().contains("int8"), "{}", plan.describe());
        let mut rng = Rng::new(22);
        let x = rng.vec_uniform(2 * 2 * 96, -1.0, 1.0);
        let mut got = Vec::new();
        plan.run_into(&m, &x, &mut PlanScratch::default(), &mut got)
            .unwrap();
        let mut want = Vec::new();
        m.forward_eager_into(
            &x,
            2,
            ConvBackend::Sliding,
            &mut crate::nn::EagerScratch::default(),
            &mut want,
        )
        .unwrap();
        assert_eq!(got.len(), want.len());
        let worst = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        // Derived bound: per-product error ≤ |x|·sw/2 + |w|·sx/2 +
        // sx·sw/4 over c_in·k products (layer 0), amplified through
        // layer 1 by at most its own absolute-weight sum per output.
        let amax = |v: &[f32]| v.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let layers = m.layers();
        let Layer::Conv { w: w0, .. } = &layers[0] else { panic!("layer 0 is a conv") };
        let Layer::Conv { w: w1, .. } = &layers[1] else { panic!("layer 1 is a conv") };
        let (xm, w0m) = (amax(&x), amax(w0));
        let (sx, sw) = (2.0 * xm / 255.0, 2.0 * w0m / 255.0);
        let e0 = (2 * 5) as f32 * (xm * sw * 0.5 + w0m * sx * 0.5 + sx * sw * 0.25) + 1e-4;
        let bound = e0 * (1.0 + (3 * 3) as f32 * amax(w1));
        assert!(
            worst <= bound,
            "quantization error {worst} exceeds the derived gate {bound}"
        );
    }

    /// Under autotune, int8 joins the probe field only for opted-in
    /// layers; the decision lands in the tune log either way and the
    /// compiled plan executes.
    #[test]
    fn autotune_probes_int8_only_for_opted_in_layers() {
        let (mc, _) = load_config(QCFG).unwrap();
        let m = Model::init(&mc, &mut Rng::new(23)).unwrap();
        let cfg = PlannerConfig {
            backend: BackendChoice::Auto,
            autotune: true,
            ..PlannerConfig::default()
        };
        // Uncommon batch so other tests cannot have pre-seeded the keys.
        let plan = Plan::compile(&m, 7, &cfg).unwrap();
        assert_eq!(plan.tuning().len(), 2);
        let t0 = &plan.tuning()[0];
        let t1 = &plan.tuning()[1];
        if !t0.cached {
            assert!(
                t0.probes.iter().any(|pr| pr.kernel == PlanKernel::QuantizedSliding),
                "int8 probed for the opted-in layer: {t0:?}"
            );
        }
        if !t1.cached {
            assert!(
                t1.probes.iter().all(|pr| pr.kernel != PlanKernel::QuantizedSliding),
                "int8 must not be probed without opt-in: {t1:?}"
            );
        }
        let mut rng = Rng::new(24);
        let x = rng.vec_uniform(7 * 2 * 96, -1.0, 1.0);
        let mut out = Vec::new();
        plan.run_into(&m, &x, &mut PlanScratch::default(), &mut out)
            .unwrap();
        assert!(out.iter().all(|v| v.is_finite()));
    }

    /// int8 never leaks into layers that did not opt in: a plain-Auto
    /// compile of a quantize-free model selects no quantized kernel and
    /// reserves no quantized scratch.
    #[test]
    fn no_opt_in_means_no_int8_anywhere() {
        let m = model();
        let plan = Plan::compile(&m, 3, &PlannerConfig::default()).unwrap();
        assert!(
            plan.layer_kernels()
                .iter()
                .all(|k| *k != PlanKernel::QuantizedSliding)
        );
        assert_eq!(plan.qbuf_len, 0);
        assert_eq!(plan.qacc_len, 0);
        assert!(plan.quant.iter().all(|q| q.is_none()));
    }
}
