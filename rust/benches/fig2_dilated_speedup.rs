//! Regenerates paper Figure 2: dilated-convolution speedup on the
//! Chaudhary et al. [4] scenario (synthetic replica of their layer
//! shapes). Paper: up to 6.8x on the small set, ≈4x across the board.
use swsnn::bench::{figs, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    let (table, rows) = figs::fig2(&cfg);
    table.emit("fig2.csv");
    let small_max = rows.iter().filter(|r| r.small_set).map(|r| r.speedup).fold(0.0f64, f64::max);
    let board: Vec<f64> = rows.iter().filter(|r| !r.small_set).map(|r| r.speedup).collect();
    let board_gm = (board.iter().map(|s| s.ln()).sum::<f64>() / board.len() as f64).exp();
    println!("small-set max speedup: {small_max:.2}x (paper: up to 6.8x)");
    println!("across-the-board geomean: {board_gm:.2}x (paper: ≈4x)");
}
