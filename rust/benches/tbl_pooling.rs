//! TBL-P: pooling as sliding sums (§2.3) vs naive window recomputation.
use swsnn::bench::{figs, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    figs::tbl_pooling(&cfg, 1_000_000, &[2, 4, 8, 16, 32, 64]).emit("tbl_pooling.csv");
}
