//! E2E serving bench: throughput/latency of the coordinator over the
//! rust-native engines (sliding vs im2col baseline) and — when
//! artifacts exist — the PJRT TCN engine. This regenerates the serving
//! numbers recorded in EXPERIMENTS.md §E2E.
use std::sync::Arc;
use swsnn::bench::Table;
use swsnn::config::{load_config, ServeConfig};
use swsnn::conv::{BackendChoice, ConvBackend};
use swsnn::coordinator::{
    serve_tcp_with, Coordinator, Engine, NativeEngine, PjrtTcnEngine, QuotaConfig, TcpClient,
    TransportConfig,
};
use swsnn::nn::{Model, Plan, PlannerConfig};
use swsnn::workload::Rng;

fn drive(coord: Arc<Coordinator>, clients: usize, per_client: usize, row: usize) -> (f64, swsnn::coordinator::CoordinatorStats) {
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(42 + c as u64);
            for _ in 0..per_client {
                let x = rng.vec_uniform(row, -1.0, 1.0);
                coord.infer(x).expect("inference");
            }
        }));
    }
    for h in handles { h.join().unwrap(); }
    let dt = t0.elapsed().as_secs_f64();
    ((clients * per_client) as f64 / dt, coord.stats())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("SWSNN_BENCH_QUICK").map_or(false, |v| v == "1");
    let per_client = if quick { 10 } else { 40 };
    let mut table = Table::new(
        "E2E serving: 8 concurrent clients through the dynamic batcher",
        &[
            "engine",
            "req/s",
            "mean batch",
            "e2e p50 µs",
            "e2e p99 µs",
            "shed (qfull/ttl)",
            "restarts",
        ],
    );
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/tcn_demo.toml"),
    )?;
    let (mc, _) = load_config(&text).map_err(anyhow::Error::msg)?;
    let serve = ServeConfig { max_batch: 8, batch_deadline_us: 2_000, ..Default::default() };

    for backend in [ConvBackend::Sliding, ConvBackend::Im2colGemm] {
        let mut rng = Rng::new(1);
        let model = Model::init(&mc, &mut rng)?;
        let row = model.c_in * model.seq_len;
        let coord = Arc::new(Coordinator::start_native(
            NativeEngine::new(model, backend, serve.max_batch), &serve)?);
        let (rps, stats) = drive(coord, 8, per_client, row);
        table.row(vec![
            format!("native/{}", backend.name()),
            format!("{rps:.1}"),
            format!("{:.2}", stats.mean_batch),
            format!("{:.0}", stats.e2e_p50_us),
            format!("{:.0}", stats.e2e_p99_us),
            format!("{}/{}", stats.shed_queue_full, stats.shed_deadline),
            format!("{}", stats.worker_restarts),
        ]);
    }

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.is_dir() {
        let dir2 = dir.clone();
        let coord = Arc::new(Coordinator::start(
            Box::new(move || Ok(Box::new(PjrtTcnEngine::from_artifacts(dir2, 42)?) as _)),
            &serve,
        )?);
        let row = coord.input_len();
        let (rps, stats) = drive(coord, 8, per_client, row);
        table.row(vec![
            "pjrt/tcn".into(),
            format!("{rps:.1}"),
            format!("{:.2}", stats.mean_batch),
            format!("{:.0}", stats.e2e_p50_us),
            format!("{:.0}", stats.e2e_p99_us),
            format!("{}/{}", stats.shed_queue_full, stats.shed_deadline),
            format!("{}", stats.worker_restarts),
        ]);
    } else {
        eprintln!("(artifacts/ missing — skipping PJRT engine row)");
    }
    table.emit("e2e_serving.csv");

    // ── Heuristic vs measured kernel choice per layer ─────────────────
    // The autotune audit table: what the shape heuristic would run,
    // what the micro-probes actually measured, and the raw probe
    // timings — so the heuristic-vs-measured decision is reviewable in
    // bench_results/BENCH_plan_autotune.json on every CI run. Runs
    // before any other autotuned compile so the probe timings are
    // recorded fresh rather than served from the tune cache.
    let mut rng = Rng::new(1);
    let model = Model::init(&mc, &mut rng)?;
    let heuristic = Plan::compile(
        &model,
        serve.max_batch,
        &PlannerConfig { backend: BackendChoice::Auto, ..PlannerConfig::default() },
    )?;
    let tuned = Plan::compile(
        &model,
        serve.max_batch,
        &PlannerConfig {
            backend: BackendChoice::Auto,
            autotune: true,
            ..PlannerConfig::default()
        },
    )?;
    let mut tune_tbl = Table::new(
        "Plan autotune: heuristic vs measured kernel per layer (batch 8)",
        &["layer", "heuristic", "measured", "from cache", "probes (µs)"],
    );
    let heur_kernels = heuristic.layer_kernels();
    for t in tuned.tuning() {
        let probes: Vec<String> = t
            .probes
            .iter()
            .map(|p| format!("{}:{:.1}", p.kernel.name(), p.micros))
            .collect();
        tune_tbl.row(vec![
            format!("{}", t.layer),
            heur_kernels[t.layer].name().to_string(),
            t.chosen.name().to_string(),
            format!("{}", t.cached),
            probes.join(" "),
        ]);
    }
    tune_tbl.emit("plan_autotune.csv");

    // ── Eager vs planned execution ────────────────────────────────────
    // Same model, same kernels available; the delta is the plan refactor
    // (compile-once shapes, single arena, fused epilogues, per-layer
    // kernel choice under `auto`, measured choice under `auto`+autotune).
    // The per-layer choices are printed so the cost model stays
    // auditable across PRs.
    let mut duel = Table::new(
        "Eager vs planned execution (8 clients through the batcher)",
        &["engine", "plan (per-layer kernels)", "req/s", "e2e p50 µs", "e2e p99 µs"],
    );
    for (choice, eager, autotune) in [
        (BackendChoice::Fixed(ConvBackend::Sliding), true, false),
        (BackendChoice::Fixed(ConvBackend::Sliding), false, false),
        (BackendChoice::Auto, false, false),
        (BackendChoice::Auto, false, true),
    ] {
        let mut rng = Rng::new(1);
        let model = Model::init(&mc, &mut rng)?;
        let row = model.c_in * model.seq_len;
        let plan_desc = if eager {
            "(eager: per-layer passes, ping-pong buffers)".to_string()
        } else {
            let cfg = PlannerConfig {
                backend: choice,
                autotune,
                ..PlannerConfig::default()
            };
            Plan::compile(&model, serve.max_batch, &cfg)?.describe()
        };
        let engine = if eager {
            let BackendChoice::Fixed(b) = choice else { unreachable!() };
            NativeEngine::eager(model, b, serve.max_batch)
        } else {
            NativeEngine::with_choice(model, choice, serve.max_batch).autotuned(autotune)
        };
        let label = engine.name();
        // The serving config must carry the autotune flag too: it gates
        // the batcher's pad-to-bucket behavior, which is what keeps the
        // probes off the request path for the "+tune" arm.
        let serve_arm = ServeConfig {
            autotune,
            ..serve.clone()
        };
        let coord = Arc::new(Coordinator::start_native(engine, &serve_arm)?);
        let (rps, stats) = drive(coord, 8, per_client, row);
        duel.row(vec![
            label,
            plan_desc,
            format!("{rps:.1}"),
            format!("{:.0}", stats.e2e_p50_us),
            format!("{:.0}", stats.e2e_p99_us),
        ]);
    }
    duel.emit("eager_vs_planned.csv");

    // ── Conv→pool fusion ──────────────────────────────────────────────
    // tcn_pool chains conv→pool pairs with non-overlapping windows, so
    // the planner fuses each pair into one arena pass; the eager row is
    // the unfused reference (identical numerics, one extra activation
    // round-trip per pair).
    let pool_text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/tcn_pool.toml"),
    )?;
    let (pool_mc, _) = load_config(&pool_text).map_err(anyhow::Error::msg)?;
    let mut fusion = Table::new(
        "Conv→pool fusion on tcn_pool (8 clients through the batcher)",
        &["engine", "plan (per-layer kernels)", "req/s", "e2e p50 µs", "e2e p99 µs"],
    );
    for eager in [true, false] {
        let mut rng = Rng::new(1);
        let model = Model::init(&pool_mc, &mut rng)?;
        let row = model.c_in * model.seq_len;
        let plan_desc = if eager {
            "(eager: per-layer passes, ping-pong buffers)".to_string()
        } else {
            let plan = Plan::compile(
                &model,
                serve.max_batch,
                &PlannerConfig {
                    backend: BackendChoice::Fixed(ConvBackend::Sliding),
                    ..PlannerConfig::default()
                },
            )?;
            format!("{} ({} fused)", plan.describe(), plan.fused_steps())
        };
        let engine = if eager {
            NativeEngine::eager(model, ConvBackend::Sliding, serve.max_batch)
        } else {
            NativeEngine::new(model, ConvBackend::Sliding, serve.max_batch)
        };
        let label = engine.name();
        let coord = Arc::new(Coordinator::start_native(engine, &serve)?);
        let (rps, stats) = drive(coord, 8, per_client, row);
        fusion.row(vec![
            label,
            plan_desc,
            format!("{rps:.1}"),
            format!("{:.0}", stats.e2e_p50_us),
            format!("{:.0}", stats.e2e_p99_us),
        ]);
    }
    fusion.emit("conv_pool_fusion.csv");

    // ── Depth-tiled chain fusion ──────────────────────────────────────
    // tcn_deep stacks eight chain-eligible layers whose dense
    // intermediates overflow L2 at batch 8; the fused plan sweeps
    // cache-resident row tiles through the whole segment, the unfused
    // plan round-trips every intermediate through the arena, and the
    // eager row adds the separate-epilogue-pass baseline. Identical
    // numerics across all three rows (pinned by tests/chain_fusion.rs);
    // the delta is pure memory locality.
    let deep_text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/tcn_deep.toml"),
    )?;
    let (deep_mc, _) = load_config(&deep_text).map_err(anyhow::Error::msg)?;
    let mut chain_tbl = Table::new(
        "Chain fusion on tcn_deep (8 clients through the batcher, batch ≤ 8)",
        &["engine", "plan (per-layer kernels)", "req/s", "e2e p50 µs", "e2e p99 µs"],
    );
    #[derive(Clone, Copy, PartialEq)]
    enum Arm {
        Eager,
        Unfused,
        Fused,
    }
    for arm in [Arm::Eager, Arm::Unfused, Arm::Fused] {
        let mut rng = Rng::new(1);
        let model = Model::init(&deep_mc, &mut rng)?;
        let row = model.c_in * model.seq_len;
        let plan_desc = match arm {
            Arm::Eager => "(eager: per-layer passes, ping-pong buffers)".to_string(),
            Arm::Unfused | Arm::Fused => {
                let plan = Plan::compile(
                    &model,
                    serve.max_batch,
                    &PlannerConfig {
                        backend: BackendChoice::Fixed(ConvBackend::Sliding),
                        fuse: arm == Arm::Fused,
                        ..PlannerConfig::default()
                    },
                )?;
                format!(
                    "{} ({} fused layers, arena {}x f32)",
                    plan.describe(),
                    plan.fused_layers(),
                    plan.arena_len()
                )
            }
        };
        let engine = match arm {
            Arm::Eager => NativeEngine::eager(model, ConvBackend::Sliding, serve.max_batch),
            Arm::Unfused => {
                NativeEngine::new(model, ConvBackend::Sliding, serve.max_batch).fused(false)
            }
            Arm::Fused => NativeEngine::new(model, ConvBackend::Sliding, serve.max_batch),
        };
        let label = engine.name();
        let coord = Arc::new(Coordinator::start_native(engine, &serve)?);
        let (rps, stats) = drive(coord, 8, per_client, row);
        chain_tbl.row(vec![
            label,
            plan_desc,
            format!("{rps:.1}"),
            format!("{:.0}", stats.e2e_p50_us),
            format!("{:.0}", stats.e2e_p99_us),
        ]);
    }
    chain_tbl.emit("chain_fusion.csv");

    // ── Serving robustness: typed shedding under steady load vs 4× ────
    // overload. The steady arm paces blocking submitters (nothing should
    // shed); the overload arm floods `try_submit` against a small queue
    // with a short TTL, so the admission layer sheds on queue depth and
    // the batcher sheds expired requests before compute — bounded queue,
    // typed errors, every request terminal. Tracked in
    // BENCH_serving_robustness.json so bench_compare.py can watch the
    // shed/restart counters alongside throughput across PRs.
    #[derive(Clone)]
    struct PacedEngine {
        row: usize,
        cost: std::time::Duration,
    }
    impl Engine for PacedEngine {
        fn input_len(&self) -> usize {
            self.row
        }
        fn output_len(&self) -> usize {
            self.row
        }
        fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            std::thread::sleep(self.cost);
            Ok(x.to_vec())
        }
        fn name(&self) -> String {
            "paced".into()
        }
    }
    let mut robust = Table::new(
        "Serving robustness: admission + deadline shedding under overload",
        &[
            "scenario",
            "offered",
            "accepted",
            "completed",
            "shed queue-full",
            "shed deadline",
            "worker lost",
            "restarts",
            "drain ms",
            "conns",
            "conn rejected",
            "quota shed",
            "decode err",
        ],
    );
    let row = 8usize;
    for (scenario, overload) in [("steady", false), ("overload 4x", true)] {
        let serve_arm = ServeConfig {
            max_batch: 4,
            batch_deadline_us: 500,
            workers: 1,
            queue_capacity: if overload { 16 } else { 1024 },
            request_ttl_ms: if overload { 5 } else { 0 },
            ..Default::default()
        };
        let engine = PacedEngine {
            row,
            cost: std::time::Duration::from_millis(1),
        };
        let coord = Arc::new(Coordinator::start_replicated(engine, &serve_arm)?);
        let clients = 8usize;
        let per = if quick { 50 } else { 200 };
        let mut handles = Vec::new();
        for c in 0..clients {
            let coord = Arc::clone(&coord);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(7 + c as u64);
                let mut tickets = Vec::new();
                for _ in 0..per {
                    let x = rng.vec_uniform(row, -1.0, 1.0);
                    if overload {
                        // Fire-and-collect: no pacing, queue fills.
                        if let Ok(t) = coord.try_submit(x) {
                            tickets.push(t);
                        }
                    } else {
                        // Paced: wait each request out (self-clocking).
                        let _ = coord.infer(x);
                    }
                }
                for t in tickets {
                    // Every accepted request must reach a terminal state.
                    t.wait_timeout(std::time::Duration::from_secs(10))
                        .expect("accepted request never reached a terminal state");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let offered = (clients * per) as u64;
        let stats = Arc::try_unwrap(coord)
            .map_err(|_| anyhow::anyhow!("coordinator still shared"))?
            .shutdown();
        robust.row(vec![
            scenario.to_string(),
            format!("{offered}"),
            format!("{}", stats.submitted),
            format!("{}", stats.completed),
            format!("{}", stats.shed_queue_full),
            format!("{}", stats.shed_deadline),
            format!("{}", stats.worker_lost),
            format!("{}", stats.worker_restarts),
            format!("{:.2}", stats.drain_ms),
            // In-process arms never touch the transport tier.
            "0".into(),
            "0".into(),
            "0".into(),
            "0".into(),
        ]);
    }

    // ── TCP + per-tenant quota arm: the same paced engine behind the ──
    // transport tier, with each tenant's back-to-back flood metered by
    // the admission token bucket. Transport counters come back over the
    // wire via the stats frame, so this row also exercises the metrics
    // endpoint itself.
    {
        let serve_arm = ServeConfig {
            max_batch: 4,
            batch_deadline_us: 500,
            workers: 1,
            queue_capacity: 64,
            ..Default::default()
        };
        let engine = PacedEngine {
            row,
            cost: std::time::Duration::from_millis(1),
        };
        let coord = Arc::new(Coordinator::start_replicated(engine, &serve_arm)?);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let server = {
            let coord = Arc::clone(&coord);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                serve_tcp_with(
                    coord,
                    "127.0.0.1:0",
                    TransportConfig {
                        max_connections: 64,
                        quota: QuotaConfig {
                            rate_per_sec: 200,
                            burst: 8,
                        },
                        ..Default::default()
                    },
                    stop,
                    move |addr| {
                        addr_tx.send(addr).unwrap();
                    },
                )
                .unwrap();
            })
        };
        let addr = addr_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        let clients = 4usize;
        let per = if quick { 50 } else { 200 };
        let mut handles = Vec::new();
        for c in 0..clients {
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(11 + c as u64);
                let mut client = TcpClient::connect(addr).unwrap();
                client.set_tenant(c as u32 + 1).unwrap();
                for _ in 0..per {
                    // Over-quota frames come back as typed code-9 sheds;
                    // the connection stays usable either way.
                    let _ = client.infer(&rng.vec_uniform(row, -1.0, 1.0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut probe = TcpClient::connect(addr).unwrap();
        let wire = probe.stats_map()?;
        drop(probe);
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        server.join().unwrap();
        let offered = (clients * per) as u64;
        let stats = Arc::try_unwrap(coord)
            .map_err(|_| anyhow::anyhow!("coordinator still shared"))?
            .shutdown();
        robust.row(vec![
            "tcp quota".to_string(),
            format!("{offered}"),
            format!("{}", stats.submitted),
            format!("{}", stats.completed),
            format!("{}", stats.shed_queue_full),
            format!("{}", stats.shed_deadline),
            format!("{}", stats.worker_lost),
            format!("{}", stats.worker_restarts),
            format!("{:.2}", stats.drain_ms),
            format!("{}", wire["conns_accepted"] as u64),
            format!("{}", wire["conns_rejected"] as u64),
            format!("{}", wire["quota_shed"] as u64),
            format!("{}", wire["decode_errors"] as u64),
        ]);
    }
    robust.emit("serving_robustness.csv");
    Ok(())
}
