//! E2E serving bench: throughput/latency of the coordinator over the
//! rust-native engines (sliding vs im2col baseline) and — when
//! artifacts exist — the PJRT TCN engine. This regenerates the serving
//! numbers recorded in EXPERIMENTS.md §E2E.
use std::sync::Arc;
use swsnn::bench::Table;
use swsnn::config::{load_config, ServeConfig};
use swsnn::conv::{BackendChoice, ConvBackend};
use swsnn::coordinator::{Coordinator, Engine, NativeEngine, PjrtTcnEngine};
use swsnn::nn::{Model, Plan, PlannerConfig};
use swsnn::workload::Rng;

fn drive(coord: Arc<Coordinator>, clients: usize, per_client: usize, row: usize) -> (f64, swsnn::coordinator::CoordinatorStats) {
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(42 + c as u64);
            for _ in 0..per_client {
                let x = rng.vec_uniform(row, -1.0, 1.0);
                coord.infer(x).expect("inference");
            }
        }));
    }
    for h in handles { h.join().unwrap(); }
    let dt = t0.elapsed().as_secs_f64();
    ((clients * per_client) as f64 / dt, coord.stats())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("SWSNN_BENCH_QUICK").map_or(false, |v| v == "1");
    let per_client = if quick { 10 } else { 40 };
    let mut table = Table::new(
        "E2E serving: 8 concurrent clients through the dynamic batcher",
        &["engine", "req/s", "mean batch", "e2e p50 µs", "e2e p99 µs"],
    );
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/tcn_demo.toml"),
    )?;
    let (mc, _) = load_config(&text).map_err(anyhow::Error::msg)?;
    let serve = ServeConfig { max_batch: 8, batch_deadline_us: 2_000, ..Default::default() };

    for backend in [ConvBackend::Sliding, ConvBackend::Im2colGemm] {
        let mut rng = Rng::new(1);
        let model = Model::init(&mc, &mut rng)?;
        let row = model.c_in * model.seq_len;
        let coord = Arc::new(Coordinator::start_native(
            NativeEngine::new(model, backend, serve.max_batch), &serve)?);
        let (rps, stats) = drive(coord, 8, per_client, row);
        table.row(vec![
            format!("native/{}", backend.name()),
            format!("{rps:.1}"),
            format!("{:.2}", stats.mean_batch),
            format!("{:.0}", stats.e2e_p50_us),
            format!("{:.0}", stats.e2e_p99_us),
        ]);
    }

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.is_dir() {
        let dir2 = dir.clone();
        let coord = Arc::new(Coordinator::start(
            Box::new(move || Ok(Box::new(PjrtTcnEngine::from_artifacts(dir2, 42)?) as _)),
            &serve,
        )?);
        let row = coord.input_len();
        let (rps, stats) = drive(coord, 8, per_client, row);
        table.row(vec![
            "pjrt/tcn".into(),
            format!("{rps:.1}"),
            format!("{:.2}", stats.mean_batch),
            format!("{:.0}", stats.e2e_p50_us),
            format!("{:.0}", stats.e2e_p99_us),
        ]);
    } else {
        eprintln!("(artifacts/ missing — skipping PJRT engine row)");
    }
    table.emit("e2e_serving.csv");

    // ── Eager vs planned execution ────────────────────────────────────
    // Same model, same kernels available; the delta is the plan refactor
    // (compile-once shapes, single arena, fused epilogues, per-layer
    // kernel choice under `auto`). The per-layer choices are printed so
    // the planner's cost model stays auditable across PRs.
    let mut duel = Table::new(
        "Eager vs planned execution (8 clients through the batcher)",
        &["engine", "plan (per-layer kernels)", "req/s", "e2e p50 µs", "e2e p99 µs"],
    );
    for (choice, eager) in [
        (BackendChoice::Fixed(ConvBackend::Sliding), true),
        (BackendChoice::Fixed(ConvBackend::Sliding), false),
        (BackendChoice::Auto, false),
    ] {
        let mut rng = Rng::new(1);
        let model = Model::init(&mc, &mut rng)?;
        let row = model.c_in * model.seq_len;
        let plan_desc = if eager {
            "(eager: per-layer passes, ping-pong buffers)".to_string()
        } else {
            Plan::compile(&model, serve.max_batch, &PlannerConfig { backend: choice })?.describe()
        };
        let engine = if eager {
            let BackendChoice::Fixed(b) = choice else { unreachable!() };
            NativeEngine::eager(model, b, serve.max_batch)
        } else {
            NativeEngine::with_choice(model, choice, serve.max_batch)
        };
        let label = engine.name();
        let coord = Arc::new(Coordinator::start_native(engine, &serve)?);
        let (rps, stats) = drive(coord, 8, per_client, row);
        duel.row(vec![
            label,
            plan_desc,
            format!("{rps:.1}"),
            format!("{:.0}", stats.e2e_p50_us),
            format!("{:.0}", stats.e2e_p99_us),
        ]);
    }
    duel.emit("eager_vs_planned.csv");
    Ok(())
}
