//! TBL-A: the §3 sliding-sum algorithm family (Algorithms 1–4, linear vs
//! log-depth variants) against the O(wN) naive baseline, plus the
//! sliding-minimum table (the paper's associative-speedup example) and
//! TBL-A3, the worker-pool thread scaling of the chunk+halo dispatch.
use swsnn::bench::{figs, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    let n = 1_000_000;
    for p in [8usize, 16, 32, 64] {
        figs::tbl_algorithms(&cfg, n, p, &[2, 4, 8, 12, 15, 31])
            .emit(&format!("tbl_algorithms_p{p}.csv"));
    }
    figs::tbl_sliding_min(&cfg, n, 64, &[4, 8, 15, 31, 63]).emit("tbl_sliding_min.csv");
    figs::tbl_sliding_scaling(&cfg, 4_000_000, 15, &[1, 2, 4, 8])
        .emit("tbl_algorithms_scaling.csv");
}
