//! Regenerates paper Figure 1: speedup of sliding 1-D convolution over
//! the im2col+GEMM (MlasConv-style) baseline across filter sizes on a
//! large 1-D input. Shape criterion: sliding wins from small k and the
//! speedup grows ≈ log k (EXPERIMENTS.md §FIG1). Also emits Fig 1b, the
//! measured worker-pool thread scaling of the same kernel — the paper's
//! `P` axis.
use swsnn::bench::{figs, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    let n = 1_000_000;
    let ks = [2usize, 3, 5, 7, 15, 31, 63, 127, 255];
    let (table, rows) = figs::fig1(&cfg, n, &ks);
    table.emit("fig1.csv");
    // Shape check: monotone-ish growth of speedup with log k.
    let first = rows.first().unwrap().speedup;
    let last = rows.last().unwrap().speedup;
    println!("speedup k={}: {:.2}x → k={}: {:.2}x (growth {:.2}x)",
        rows.first().unwrap().k, first, rows.last().unwrap().k, last, last / first);

    // Fig 1b: thread scaling on the k=63 hot shape.
    let (scaling, srows) = figs::fig1_scaling(&cfg, n, 63, &[1, 2, 4, 8]);
    scaling.emit("fig1_scaling.csv");
    if let Some(r4) = srows.iter().find(|r| r.threads == 4) {
        println!("thread scaling at 4T: {:.2}x vs 1T (target ≥ 2x)", r4.speedup);
    }
}
