//! TBL-Q — int8 quantized sliding conv vs the f32 sliding kernel and
//! the im2col+GEMM baseline, across the paper's Fig-1-style shapes
//! (long single-channel rows, growing k) plus multi-channel TCN-ish
//! shapes. The int8 arm times the *whole* pipeline the planner runs per
//! request — activation range scan, quantize, quantized conv — so the
//! speedup column is honest about quantization overhead, not just the
//! inner kernel.
use swsnn::bench::{bench, fmt_duration, BenchConfig, Table};
use swsnn::conv::{
    conv1d_im2col_epilogue_into, conv1d_quantized_into, conv1d_sliding_with_into,
    quantized_scratch_len, Conv1dParams, QuantParams,
};
use swsnn::exec::Executor;
use swsnn::ops::Epilogue;
use swsnn::workload::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let ex1 = Executor::new(1);
    let mut rng = Rng::new(0x18B1);
    let cases: Vec<Conv1dParams> = vec![
        Conv1dParams::new(1, 1, 1_000_000, 3),
        Conv1dParams::new(1, 1, 1_000_000, 15),
        Conv1dParams::new(1, 1, 1_000_000, 63),
        Conv1dParams::new(8, 16, 100_000, 5),
        Conv1dParams::new(16, 16, 50_000, 3).with_dilation(4).with_same_pad(),
    ];
    let mut table = Table::new(
        "TBL-Q — f32 sliding vs int8 quantized sliding vs im2col+GEMM (1 thread)",
        &["c_in", "c_out", "n", "k", "dil", "f32_sliding", "int8_sliding", "im2col_gemm", "int8_speedup"],
    );
    for p in &cases {
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
        let bias = Some(b.as_slice());

        let mut y = vec![0.0f32; p.y_len()];
        let m_f32 = bench(&cfg, || {
            conv1d_sliding_with_into(
                &ex1,
                std::hint::black_box(&x),
                &w,
                bias,
                p,
                Epilogue::None,
                std::hint::black_box(&mut y),
            );
        });

        // int8 pipeline, weights pre-quantized once (plan compile does
        // this too); activations scanned + quantized per call.
        let wp = QuantParams::from_slice(&w);
        let qw = wp.quantize_slice(&w);
        let mut qx = vec![0i8; p.x_len()];
        let mut acc = vec![0i32; quantized_scratch_len(p)];
        let m_int8 = bench(&cfg, || {
            let xp = QuantParams::from_slice(std::hint::black_box(&x));
            xp.quantize_slice_into(&x, &mut qx);
            conv1d_quantized_into(
                &qx,
                &qw,
                xp,
                wp,
                bias,
                p,
                Epilogue::None,
                &mut acc,
                std::hint::black_box(&mut y),
            );
        });

        let mut col = vec![0.0f32; p.c_in * p.k * p.n_out()];
        let m_gemm = bench(&cfg, || {
            conv1d_im2col_epilogue_into(
                &ex1,
                std::hint::black_box(&x),
                &w,
                bias,
                p,
                Epilogue::None,
                &mut col,
                std::hint::black_box(&mut y),
            );
        });

        let speedup = m_f32.median_ns() / m_int8.median_ns();
        table.row(vec![
            p.c_in.to_string(),
            p.c_out.to_string(),
            p.n.to_string(),
            p.k.to_string(),
            p.dilation.to_string(),
            fmt_duration(m_f32.median),
            fmt_duration(m_int8.median),
            fmt_duration(m_gemm.median),
            format!("{speedup:.2}x"),
        ]);
    }
    table.emit("quantized.csv");
}
