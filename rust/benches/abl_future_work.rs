//! Ablations for the paper's §5 future-work items, all implemented here:
//!
//! * 2-D convolution ("extending … to more than one dimension") —
//!   sliding vs im2col, where the expansion factor is kh·kw;
//! * custom small-filter kernels (k = 3, 5) — fused single-pass vs the
//!   generic slid-accumulate schedule;
//! * matmul reformulation (tap-GEMM, the MXU-shaped form) — measured on
//!   CPU for completeness (it targets matmul accelerators);
//! * int8 quantized sliding conv vs f32 ("quantization is not entangled
//!   with GEMM").
use swsnn::bench::{bench, fmt_duration, BenchConfig, Table};
use swsnn::conv::{
    conv1d, conv1d_quantized, conv1d_small_k, conv1d_tap_gemm, conv2d_im2col, conv2d_sliding,
    Conv1dParams, Conv2dParams, ConvBackend, QuantParams,
};
use swsnn::workload::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::new(0xAB2);

    // ── 2-D convolution ──────────────────────────────────────────────
    let mut t2d = Table::new(
        "ABL-2D — conv2d sliding vs im2col+GEMM (c_in=c_out=4, same-pad)",
        &["hxw", "k", "im2col", "sliding", "speedup"],
    );
    for (hw, k) in [(64usize, 3usize), (64, 5), (128, 3), (128, 5), (128, 7), (256, 3)] {
        let p = Conv2dParams::new(4, 4, hw, hw, k, k).with_same_pad();
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let mg = bench(&cfg, || {
            std::hint::black_box(conv2d_im2col(std::hint::black_box(&x), &w, None, &p));
        });
        let ms = bench(&cfg, || {
            std::hint::black_box(conv2d_sliding(std::hint::black_box(&x), &w, None, &p));
        });
        t2d.row(vec![
            format!("{hw}x{hw}"),
            k.to_string(),
            fmt_duration(mg.median),
            fmt_duration(ms.median),
            format!("{:.2}x", mg.median_ns() / ms.median_ns()),
        ]);
    }
    t2d.emit("abl_conv2d.csv");

    // ── small-filter custom kernels ──────────────────────────────────
    let mut tsk = Table::new(
        "ABL-SK — fused small-k kernels vs generic sliding (N=1M, valid)",
        &["k", "generic sliding", "fused kernel", "speedup"],
    );
    let n = 1_000_000;
    let x = rng.vec_uniform(n, -1.0, 1.0);
    for k in [3usize, 5] {
        let w = rng.vec_uniform(k, -1.0, 1.0);
        let p = Conv1dParams::new(1, 1, n, k);
        let mgen = bench(&cfg, || {
            std::hint::black_box(conv1d(ConvBackend::Sliding, std::hint::black_box(&x), &w, None, &p));
        });
        let mfused = bench(&cfg, || {
            std::hint::black_box(conv1d_small_k(std::hint::black_box(&x), &w, None, &p).unwrap());
        });
        tsk.row(vec![
            k.to_string(),
            fmt_duration(mgen.median),
            fmt_duration(mfused.median),
            format!("{:.2}x", mgen.median_ns() / mfused.median_ns()),
        ]);
    }
    tsk.emit("abl_small_k.csv");

    // ── matmul reformulation ─────────────────────────────────────────
    let mut tmm = Table::new(
        "ABL-MM — tap-GEMM reformulation (MXU-shaped) vs sliding FMA on CPU",
        &["shape", "sliding", "tap_gemm", "im2col"],
    );
    for (n, c, k) in [(8192usize, 4usize, 7usize), (8192, 16, 3), (4096, 32, 3)] {
        let p = Conv1dParams::new(c, c, n, k).with_same_pad();
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let ms = bench(&cfg, || {
            std::hint::black_box(conv1d(ConvBackend::Sliding, std::hint::black_box(&x), &w, None, &p));
        });
        let mt = bench(&cfg, || {
            std::hint::black_box(conv1d_tap_gemm(std::hint::black_box(&x), &w, None, &p).unwrap());
        });
        let mg = bench(&cfg, || {
            std::hint::black_box(conv1d(ConvBackend::Im2colGemm, std::hint::black_box(&x), &w, None, &p));
        });
        tmm.row(vec![
            format!("n{n}_c{c}_k{k}"),
            fmt_duration(ms.median),
            fmt_duration(mt.median),
            fmt_duration(mg.median),
        ]);
    }
    tmm.emit("abl_tap_gemm.csv");

    // ── quantized path ───────────────────────────────────────────────
    let mut tq = Table::new(
        "ABL-Q — int8 sliding conv vs f32 sliding conv (N=1M, valid)",
        &["k", "f32 sliding", "int8 sliding", "speedup"],
    );
    for k in [7usize, 15, 31] {
        let p = Conv1dParams::new(1, 1, n, k);
        let w = rng.vec_uniform(k, -0.5, 0.5);
        let xq_p = QuantParams::from_range(-1.0, 1.0);
        let wq_p = QuantParams::from_range(-0.5, 0.5);
        let qx = xq_p.quantize_slice(&x);
        let qw = wq_p.quantize_slice(&w);
        let mf = bench(&cfg, || {
            std::hint::black_box(conv1d(ConvBackend::Sliding, std::hint::black_box(&x), &w, None, &p));
        });
        let mq = bench(&cfg, || {
            std::hint::black_box(conv1d_quantized(std::hint::black_box(&qx), &qw, xq_p, wq_p, &p));
        });
        tq.row(vec![
            k.to_string(),
            fmt_duration(mf.median),
            fmt_duration(mq.median),
            format!("{:.2}x", mf.median_ns() / mq.median_ns()),
        ]);
    }
    tq.emit("abl_quantized.csv");
}
