//! TBL-S: the §2.1 prefix-sum substrate — sequential vs Hillis–Steele vs
//! Blelloch scans, sequential vs tree reduce.
use swsnn::bench::{figs, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    figs::tbl_scan(&cfg, &[1_000, 10_000, 100_000, 1_000_000]).emit("tbl_scan.csv");
    figs::tbl_backends(&cfg, 262_144, &[3, 7, 15, 31]).emit("tbl_backends.csv");
}
