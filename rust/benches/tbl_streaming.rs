//! TBL-STREAM: per-sample cost of stateful streaming sessions vs the
//! stateless baseline that recomputes the full forward on every
//! arriving packet. The session replays the fused chain incrementally
//! over slab-backed halo rings (amortized O(1) work per sample); the
//! recompute baseline pays one whole batch-1 plan run per packet, so
//! its per-sample cost scales with `seq_len / packet`. Emits
//! `bench_results/BENCH_streaming.json` under `--json`.
use swsnn::bench::{bench, BenchConfig, Table};
use swsnn::config::load_config;
use swsnn::conv::{BackendChoice, ConvBackend};
use swsnn::exec::Executor;
use swsnn::nn::{Model, Plan, PlanScratch, PlannerConfig, Session};
use swsnn::workload::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env();
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/tcn_stream.toml"),
    )?;
    let (mc, _) = load_config(&text).map_err(anyhow::Error::msg)?;
    let model = Model::init(&mc, &mut Rng::new(1))?;
    let pcfg = PlannerConfig {
        backend: BackendChoice::Fixed(ConvBackend::Sliding),
        ..PlannerConfig::default()
    };
    let plan = Plan::compile(&model, 1, &pcfg)?;
    let n = mc.seq_len;
    let c_in = mc.c_in;
    let planar = Rng::new(2).vec_uniform(c_in * n, -1.0, 1.0);
    // Interleave planar [c, n] to the session wire order [t, c].
    let mut stream = vec![0.0f32; planar.len()];
    for t in 0..n {
        for ch in 0..c_in {
            stream[t * c_in + ch] = planar[ch * n + t];
        }
    }

    let mut sess = Session::open(&plan, &model)?;
    let mut dst = vec![0.0f32; sess.spec().out_len() * sess.spec().out_channels()];
    let ex = Executor::new(1);
    let mut scratch = PlanScratch::default();
    let mut full = Vec::new();
    plan.run_with_into(&ex, &model, &planar, &mut scratch, &mut full)?; // warm

    let mut table = Table::new(
        &format!("Streaming session step vs full recompute per packet ({}, seq {n})", mc.name),
        &["packet", "session ns/sample", "recompute ns/sample", "speedup", "slab grows"],
    );
    for &packet in &[1usize, 4, 16] {
        // One full stream replay through the session, `packet` samples
        // per step. Steady-state steps are allocation-free, so the
        // replay cost is the amortized per-sample cost × seq_len.
        let m_sess = bench(&cfg, || {
            sess.reset();
            for chunk in stream.chunks(packet * c_in) {
                sess.step_into(&model, chunk, &mut dst).unwrap();
            }
        });
        let sess_ns = m_sess.median_ns() / n as f64;
        // Stateless baseline: every arriving packet reruns the whole
        // batch-1 plan on the full history — per-sample cost is one
        // forward divided by the packet size.
        let m_full = bench(&cfg, || {
            plan.run_with_into(&ex, &model, &planar, &mut scratch, &mut full)
                .unwrap();
        });
        let re_ns = m_full.median_ns() / packet as f64;
        table.row(vec![
            format!("{packet}"),
            format!("{sess_ns:.1}"),
            format!("{re_ns:.1}"),
            format!("{:.2}x", re_ns / sess_ns),
            format!("{}", sess.grows()),
        ]);
    }
    table.emit("streaming.csv");
    Ok(())
}
